//! Pass 4 — concurrency lint: the lock-order registry.
//!
//! The crate owns exactly three long-lived mutexes (the session task
//! queue, the executor's block-plan work queue, and the facade's
//! pricing state). None of them is ever held while acquiring another —
//! that absence of nesting is the concurrency invariant the serving
//! path's deadlock-freedom rests on, and this registry pins it: every
//! `Mutex` must be listed in [`LOCKS`], every may-hold-while-acquiring
//! relationship in [`ALLOWED_NESTINGS`], and [`check_lock_order`]
//! proves the nesting graph acyclic (Kahn's algorithm). A unit test in
//! this module additionally censuses `Mutex::new` sites across the
//! source tree, so adding a mutex without registering it fails `cargo
//! test`, and [`analyze_graph`](super::analyze_graph) runs the cycle
//! check on every analyzer invocation.

/// Every long-lived `std::sync::Mutex` in the crate, by stable name.
pub const LOCKS: &[&str] = &[
    // `coordinator::session`: the worker pool's shared task receiver
    // (`Arc<Mutex<Receiver<Task>>>`), locked only around `recv`.
    "coordinator::session::task_queue",
    // `coordinator::executor::run_plans`: the block-plan work queue the
    // per-layer worker pool pops from.
    "coordinator::executor::plan_queue",
    // `api`: the corner/pricing state re-priced at runtime by
    // `Yodann::set_corner` and read per frame.
    "api::pricing",
];

/// Allowed may-hold-while-acquiring edges `(held, acquired)`.
///
/// Deliberately empty: no code path in the crate acquires a mutex while
/// holding another. Add an edge here (keeping the graph acyclic) before
/// introducing such a path.
pub const ALLOWED_NESTINGS: &[(&str, &str)] = &[];

/// Prove the nesting graph acyclic. Returns a total acquisition order
/// consistent with [`ALLOWED_NESTINGS`], or a description of the cycle.
pub fn check_lock_order() -> Result<Vec<&'static str>, String> {
    topo_order(LOCKS, ALLOWED_NESTINGS)
}

/// Kahn's algorithm over an edge list; `Err` names the cyclic residue.
fn topo_order(
    nodes: &[&'static str],
    edges: &[(&'static str, &'static str)],
) -> Result<Vec<&'static str>, String> {
    let idx = |name: &str| nodes.iter().position(|&n| n == name);
    let mut indegree = vec![0usize; nodes.len()];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for &(held, acquired) in edges {
        match (idx(held), idx(acquired)) {
            (Some(h), Some(a)) => {
                adj[h].push(a);
                indegree[a] += 1;
            }
            _ => {
                return Err(format!(
                    "nesting edge ({held}, {acquired}) names an unregistered lock"
                ))
            }
        }
    }
    let mut ready: Vec<usize> = (0..nodes.len()).filter(|&i| indegree[i] == 0).collect();
    let mut order = Vec::with_capacity(nodes.len());
    while let Some(i) = ready.pop() {
        order.push(nodes[i]);
        for &j in &adj[i] {
            indegree[j] -= 1;
            if indegree[j] == 0 {
                ready.push(j);
            }
        }
    }
    if order.len() == nodes.len() {
        Ok(order)
    } else {
        let cyclic: Vec<&str> =
            (0..nodes.len()).filter(|&i| indegree[i] > 0).map(|i| nodes[i]).collect();
        Err(format!("lock-order cycle through {cyclic:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn registry_is_acyclic() {
        let order = check_lock_order().expect("the registered nesting graph must be acyclic");
        assert_eq!(order.len(), LOCKS.len());
    }

    #[test]
    fn the_checker_detects_cycles() {
        let nodes = &["a", "b", "c"];
        let cycle = &[("a", "b"), ("b", "c"), ("c", "a")];
        assert!(topo_order(nodes, cycle).is_err());
        let chain = &[("a", "b"), ("b", "c")];
        assert_eq!(topo_order(nodes, chain).expect("chain is acyclic"), vec!["a", "b", "c"]);
    }

    #[test]
    fn unregistered_edge_endpoints_are_rejected() {
        assert!(topo_order(&["a"], &[("a", "ghost")]).is_err());
    }

    /// Census: every `Mutex::new` site in the source tree must have a
    /// registry entry. If this fails you added (or removed) a mutex —
    /// update [`LOCKS`] and, if it can nest, [`ALLOWED_NESTINGS`].
    #[test]
    fn every_mutex_in_the_tree_is_registered() {
        fn count_sites(dir: &Path, total: &mut usize) {
            for entry in std::fs::read_dir(dir).expect("src dir readable") {
                let path = entry.expect("dir entry").path();
                if path.is_dir() {
                    count_sites(&path, total);
                } else if path.extension().is_some_and(|e| e == "rs") {
                    let text = std::fs::read_to_string(&path).expect("source readable");
                    // Test modules trail their file in this codebase;
                    // mutexes built by test scaffolding are not
                    // long-lived locks and stay out of the census.
                    let non_test = text.split("#[cfg(test)]").next().unwrap_or("");
                    *total += non_test.matches("Mutex::new(").count();
                }
            }
        }
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let mut sites = 0;
        count_sites(&src, &mut sites);
        assert_eq!(
            sites,
            LOCKS.len(),
            "found {sites} `Mutex::new` sites but {} registry entries — \
             register new mutexes in analysis::locks::LOCKS",
            LOCKS.len()
        );
    }
}
