//! The fault-injection subsystem's behavioral contract (ISSUE 7):
//! disabled injection is bit-identical to the uninstrumented path on
//! every engine × policy, seeded injection is reproducible (same seed →
//! same flips, same outputs, same `FaultReport`), detected faults fail
//! only their own frame with a typed error while the session keeps
//! serving, weight-memory faults reject at session build, and tickets
//! stay redeemable across session teardown.

use std::sync::Arc;
use std::time::Duration;

use yodann::api::{FrameResult, SessionBuilder, YodannError};
use yodann::coordinator::{SessionLayerSpec, ShardGrid, ShardPolicy};
use yodann::engine::EngineKind;
use yodann::fault::{FaultPlan, FaultReport, FaultSite};
use yodann::fixedpoint::Q2_9;
use yodann::testkit::Gen;
use yodann::workload::{synthetic_scene, BinaryKernels, Image, ScaleBias};

fn two_layer_specs(seed: u64) -> Vec<SessionLayerSpec> {
    let mut g = Gen::new(seed);
    let sb = |n: usize| ScaleBias { alpha: vec![Q2_9.from_f64(0.1); n], beta: vec![0; n] };
    vec![
        SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 6, 3, 3)),
            scale_bias: Arc::new(sb(6)),
            relu: true,
            maxpool2: true,
        },
        SessionLayerSpec {
            k: 5,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 4, 6, 5)),
            scale_bias: Arc::new(sb(4)),
            relu: false,
            maxpool2: false,
        },
    ]
}

fn frames(n: usize, seed: u64) -> Vec<Image> {
    let mut g = Gen::new(seed);
    (0..n).map(|_| synthetic_scene(&mut g, 3, 8, 8)).collect()
}

fn session(
    kind: EngineKind,
    policy: ShardPolicy,
    plan: FaultPlan,
) -> Result<yodann::api::Yodann, YodannError> {
    SessionBuilder::new()
        .layers(two_layer_specs(40))
        .engine(kind)
        .workers(2)
        .shard_policy(policy)
        .max_in_flight(8)
        .fault_plan(plan)
        .build()
}

/// Submit frames one at a time so every frame is its own dispatch batch
/// — the injection draws then depend only on the plan seed, not on how
/// the dispatcher happened to group a burst.
fn run_serial(
    sess: &mut yodann::api::Yodann,
    frames: &[Image],
) -> Vec<Result<FrameResult, YodannError>> {
    frames
        .iter()
        .map(|f| sess.submit(f.clone()).and_then(|t| t.wait()))
        .collect()
}

fn outputs(results: &[Result<FrameResult, YodannError>]) -> Vec<Image> {
    results
        .iter()
        .map(|r| r.as_ref().expect("frame should compute").output.clone())
        .collect()
}

fn policies() -> [ShardPolicy; 4] {
    [
        ShardPolicy::PerFrame,
        ShardPolicy::RowBands(2),
        ShardPolicy::PerShard(ShardGrid::striped(2)),
        ShardPolicy::Auto,
    ]
}

#[test]
fn disabled_injection_is_bit_identical_for_every_engine_and_policy() {
    // The conformance obligation: an armed-but-disabled FaultPlan (the
    // explicit opt-out, which also beats a YODANN_FAULT_SEED env arm)
    // must leave every engine × policy exactly on the uninstrumented
    // numbers.
    let fs = frames(3, 50);
    // One uninstrumented reference per engine family: the multi-bit
    // kinds are bit-identical to `functional`, the binary-activation
    // kinds to `xnor` (a different function of the same weights).
    let mut reference =
        session(EngineKind::Functional, ShardPolicy::PerFrame, FaultPlan::disabled()).unwrap();
    let want_multibit = outputs(&run_serial(&mut reference, &fs));
    let mut reference =
        session(EngineKind::Xnor, ShardPolicy::PerFrame, FaultPlan::disabled()).unwrap();
    let want_binary = outputs(&run_serial(&mut reference, &fs));
    for kind in EngineKind::ALL {
        let want = if kind.is_binary() { &want_binary } else { &want_multibit };
        for policy in policies() {
            let mut sess = session(kind, policy, FaultPlan::disabled()).unwrap();
            let got = run_serial(&mut sess, &fs);
            for (i, r) in got.iter().enumerate() {
                let r = r.as_ref().unwrap_or_else(|e| {
                    panic!("{} {policy} frame {i}: {e}", kind.name());
                });
                assert_eq!(
                    r.output,
                    want[i],
                    "disabled injection must be bit-identical ({} {policy} frame {i})",
                    kind.name()
                );
                assert_eq!(
                    r.telemetry.fault,
                    FaultReport::default(),
                    "disabled injection must report nothing ({} {policy})",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn same_seed_reproduces_outputs_and_fault_reports() {
    let fs = frames(3, 51);
    let plan = || FaultPlan::seeded(7).ber(1e-2).detect(false);
    let run = || {
        let mut sess = session(EngineKind::Functional, ShardPolicy::PerFrame, plan()).unwrap();
        let results = run_serial(&mut sess, &fs);
        results
            .into_iter()
            .map(|r| r.expect("detect-off frames never fail"))
            .map(|r| (r.output, r.telemetry.fault))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), b.len());
    for (i, ((oa, fa), (ob, fb))) in a.iter().zip(&b).enumerate() {
        assert_eq!(oa, ob, "frame {i}: same seed must produce identical corrupted outputs");
        assert_eq!(fa, fb, "frame {i}: same seed must produce identical fault reports");
        assert!(fa.total_flips() > 0, "frame {i}: a 1e-2 BER must actually flip bits");
    }
    // And the corruption is real: a clean session disagrees.
    let mut clean =
        session(EngineKind::Functional, ShardPolicy::PerFrame, FaultPlan::disabled()).unwrap();
    let want = outputs(&run_serial(&mut clean, &fs));
    assert!(
        a.iter().zip(&want).any(|((o, _), w)| o != w),
        "silent injection at 1e-2 BER should corrupt at least one frame"
    );
}

#[test]
fn detected_faults_fail_only_their_frame_with_a_typed_error() {
    // Saturated image/halo BER with checksums on: every frame must come
    // back as FaultDetected (tagged with its own ticket id), the session
    // must keep admitting frames afterwards, and no frame may ever
    // deliver silently corrupted data.
    let fs = frames(3, 52);
    for policy in [ShardPolicy::PerFrame, ShardPolicy::RowBands(2)] {
        let plan = FaultPlan::seeded(3).ber(1.0).weights(false);
        let mut sess = session(EngineKind::Functional, policy, plan).unwrap();
        for (i, r) in run_serial(&mut sess, &fs).into_iter().enumerate() {
            let e = r.err().unwrap_or_else(|| panic!("{policy} frame {i}: should be refused"));
            match &e {
                YodannError::FaultDetected { frame: Some(fr), site, .. } => {
                    assert_eq!(*fr, i as u64, "{policy}: error must carry the ticket id");
                    assert!(
                        matches!(site, FaultSite::ImageMemory | FaultSite::HaloExchange),
                        "{policy}: weights are off, site was {site}"
                    );
                }
                other => panic!("{policy} frame {i}: expected FaultDetected, got {other}"),
            }
            assert!(e.to_string().contains("uncorrectable"), "{e}");
        }
        // The session survived three refused frames.
        assert!(sess.submit(fs[0].clone()).is_ok(), "{policy}: session must keep serving");
    }
}

#[test]
fn silent_corruption_serves_but_diverges() {
    let fs = frames(2, 53);
    let mut clean =
        session(EngineKind::Functional, ShardPolicy::RowBands(2), FaultPlan::disabled()).unwrap();
    let want = outputs(&run_serial(&mut clean, &fs));
    let plan = FaultPlan::seeded(4).ber(1.0).detect(false);
    let mut sess = session(EngineKind::Functional, ShardPolicy::RowBands(2), plan).unwrap();
    for (i, r) in run_serial(&mut sess, &fs).into_iter().enumerate() {
        let r = r.expect("detection is off: frames serve");
        assert_ne!(r.output, want[i], "saturated BER must corrupt frame {i}");
        assert!(r.telemetry.fault.total_flips() > 0);
        assert_eq!(r.telemetry.fault.detected, 0, "nothing detects with checksums off");
    }
}

#[test]
fn weight_faults_reject_the_session_at_build_when_detected() {
    // Weights pack once at session build; a saturated weight BER with
    // detection on must refuse the whole session (no frame exists yet).
    let plan = FaultPlan::seeded(5).ber(1.0).image(false).halo(false);
    let e = session(EngineKind::Functional, ShardPolicy::PerFrame, plan).err();
    match e {
        Some(YodannError::FaultDetected { frame: None, site: FaultSite::WeightMemory, .. }) => {}
        other => panic!("expected a build-time WeightMemory FaultDetected, got {other:?}"),
    }
    // With detection off the session builds and serves corrupted
    // outputs, reporting the session-lifetime weight flips per frame.
    let fs = frames(2, 54);
    let mut clean =
        session(EngineKind::Functional, ShardPolicy::PerFrame, FaultPlan::disabled()).unwrap();
    let want = outputs(&run_serial(&mut clean, &fs));
    let plan = FaultPlan::seeded(5).ber(1.0).image(false).halo(false).detect(false);
    let mut sess = session(EngineKind::Functional, ShardPolicy::PerFrame, plan).unwrap();
    for (i, r) in run_serial(&mut sess, &fs).into_iter().enumerate() {
        let r = r.expect("detection is off: frames serve");
        assert_ne!(r.output, want[i], "corrupted weights must change frame {i}");
        assert!(r.telemetry.fault.weight_flips > 0);
        assert_eq!(r.telemetry.fault.image_flips, 0);
    }
}

#[test]
fn tickets_survive_session_teardown_and_deadlines_are_typed() {
    let fs = frames(1, 55);
    let mut sess =
        session(EngineKind::Functional, ShardPolicy::PerFrame, FaultPlan::disabled()).unwrap();
    let mut ticket = sess.submit(fs[0].clone()).unwrap();
    // Dropping the session drains in-flight frames first, so the
    // outstanding ticket still redeems — here through the deadline API.
    drop(sess);
    let r = ticket.wait_timeout(Duration::from_secs(5)).expect("drained frame redeems");
    assert_eq!(r.frame_id, 0);
    assert_eq!(r.telemetry.fault, FaultReport::default());
}
