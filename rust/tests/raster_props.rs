//! Property tests of the layer-resident bitplane raster: window
//! extraction must be bit-equal to the naive per-window packing of PR 1
//! on arbitrary images — including zero-pad halo positions and
//! valid-mode edges — and the raster-based functional engine must match
//! the per-window baseline on any blocked/tiled layer geometry. Also
//! pins the steady-state scratch-reuse guarantee the batched serving
//! path relies on.

use yodann::coordinator::{decompose, run_layer_engine, ExecOptions, LayerWorkload};
use yodann::engine::raster::{BitplaneRaster, OFFSET, PLANES};
use yodann::engine::{ConvEngine, CycleAccurate, EngineKind, Functional};
use yodann::hw::{BlockJob, ChipConfig};
use yodann::testkit::{property, Gen};
use yodann::workload::{
    random_image, reference_conv, reference_xnor_conv, BinaryKernels, Image, ScaleBias,
};

/// The PR-1 inner loop as the oracle: pack one window's 12 offset-binary
/// plane words (and Σu) straight from the image, bit by bit.
fn naive_window(
    img: &Image,
    k: usize,
    zero_pad: bool,
    c: usize,
    y: usize,
    x: usize,
) -> ([u64; PLANES], i64) {
    let offset = if zero_pad { ((k - 1) / 2) as isize } else { 0 };
    let mut planes = [0u64; PLANES];
    let mut sum_u = 0i64;
    let mut j = 0u32;
    for dy in 0..k {
        for dx in 0..k {
            let ty = y as isize + dy as isize - offset;
            let tx = x as isize + dx as isize - offset;
            let px = img.at_padded(c, ty, tx);
            let mut u = (px + OFFSET) as u64;
            sum_u += u as i64;
            while u != 0 {
                planes[u.trailing_zeros() as usize] |= 1u64 << j;
                u &= u - 1;
            }
            j += 1;
        }
    }
    (planes, sum_u)
}

#[test]
fn prop_window_extraction_equals_naive_packing() {
    // ANY random geometry, full Q2.9 amplitude, every output position —
    // halo corners, valid-mode edges and windows straddling one or two
    // u64 word boundaries (w up to 130) included.
    property("raster window == naive pack", 0x8A57E8, 40, |g| {
        let k = g.range(1, 7);
        let zero_pad = g.bool();
        let c = g.range(1, 3);
        let h = g.range(k, 12);
        let w = match g.range(0, 2) {
            0 => g.range(k, 12),
            1 => g.range(60, 70),  // windows straddle the first word boundary
            _ => g.range(126, 130), // and the second
        };
        let img = random_image(g, c, h, w, *g.choose(&[0.05, 1.0]));
        let mut r = BitplaneRaster::new();
        r.pack(&img, k, zero_pad);
        let (out_h, out_w) =
            if zero_pad { (h, w) } else { (h + 1 - k, w + 1 - k) };
        let mut planes = [0u64; PLANES];
        for ch in 0..c {
            for y in 0..out_h {
                for x in 0..out_w {
                    let sum_u = r.window(ch, y, x, &mut planes);
                    let (want, want_u) = naive_window(&img, k, zero_pad, ch, y, x);
                    assert_eq!(
                        (planes, sum_u),
                        (want, want_u),
                        "k={k} pad={zero_pad} c={ch} y={y} x={x} ({h}x{w})"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_raster_engine_equals_per_window_engine() {
    // The refactor's layer-level obligation, old vs new functional: any
    // channel-blocked, vertically tiled, saturating geometry — identical
    // outputs whether windows come from the layer-resident raster or the
    // per-window repack.
    property("raster functional == pr1 functional", 0x8A57E9, 25, |g| {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * g.range(8, 24); // shrink h_max → tiling
        let k = g.range(1, 7);
        let n_in = g.range(1, 10);
        let n_out = g.range(1, 12);
        let zero_pad = g.bool();
        let h = g.range(k.max(2), 26);
        let w = g.range(k.max(2), 10);
        let amplitude = *g.choose(&[0.01, 0.05, 0.4]);
        let wl = LayerWorkload {
            k,
            zero_pad,
            input: random_image(g, n_in, h, w, amplitude),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::random(g, n_out),
        };
        let workers = g.range(1, 4);
        let new = run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::Functional);
        let old =
            run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::FunctionalPerWindow);
        assert_eq!(
            new.output, old.output,
            "k={k} n_in={n_in} n_out={n_out} pad={zero_pad} h={h} w={w} amp={amplitude}"
        );
        assert_eq!(new.blocks, old.blocks);
        assert_eq!(new.stats.useful_ops, old.stats.useful_ops);
    });
}

#[test]
fn session_style_frame_loop_has_zero_steady_state_allocs() {
    // A session worker repacks its one raster scratch per (frame, layer)
    // with layer geometries alternating within each frame. After the
    // first frame warms the buffers to the largest layer, no further
    // frame may allocate.
    let mut g = Gen::new(0x5C7A);
    let mut raster = BitplaneRaster::new();
    let frame_layers = |g: &mut Gen| {
        vec![
            random_image(g, 3, 20, 16, 0.1), // layer 1 input, k=3 padded
            random_image(g, 6, 10, 8, 0.1),  // layer 2 input, k=5 padded
        ]
    };
    for img in frame_layers(&mut g) {
        raster.pack(&img, if img.c == 3 { 3 } else { 5 }, true);
    }
    let warm = raster.reallocs();
    for _ in 0..5 {
        for img in frame_layers(&mut g) {
            raster.pack(&img, if img.c == 3 { 3 } else { 5 }, true);
        }
    }
    assert_eq!(raster.reallocs(), warm, "steady-state frames must not allocate");
}

#[test]
fn k5_k7_tiles_thinner_than_the_halo_stay_correct() {
    // The k ≤ 3 analog was pinned by PR 2's
    // `thin_tiles_near_the_top_stay_correct`; this is the k = 5/7 audit:
    // h_max barely ≥ k forces 1-row tiles whose interior `row_base`
    // sits below the halo offset *and* whose bottoms clip at the image
    // edge — on thin (h < k) and regular images, every engine, against
    // the software reference.
    for (k, h_max, h) in
        [(5usize, 5usize, 3usize), (5, 6, 17), (7, 7, 4), (7, 8, 23), (7, 7, 20)]
    {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = h_max * 4;
        let mut g = Gen::new(0x7714 ^ (k * 100 + h) as u64);
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, 3, h, 8, 0.4),
            kernels: BinaryKernels::random(&mut g, 5, 3, k),
            scale_bias: ScaleBias::random(&mut g, 5),
        };
        let want = reference_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
        for kind in EngineKind::MULTI_BIT {
            let run = run_layer_engine(&wl, &cfg, ExecOptions { workers: 2 }, kind);
            assert_eq!(
                run.output,
                want,
                "k={k} h_max={h_max} h={h} engine {}",
                kind.name()
            );
        }
        // The binary family against its own sign reference on the same
        // thin tiles (n_in = 3 ≤ n_ch keeps the single-block Q7.9
        // accumulation order of the monolithic reference).
        let want = reference_xnor_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
        for kind in EngineKind::XNOR {
            let run = run_layer_engine(&wl, &cfg, ExecOptions { workers: 2 }, kind);
            assert_eq!(
                run.output,
                want,
                "k={k} h_max={h_max} h={h} engine {}",
                kind.name()
            );
        }
    }
}

#[test]
fn thin_tile_jobs_materialize_identically_for_k5_k7() {
    // The materialized front door (`materialize_block`, what the cycle
    // engine consumes) and the functional engine's `pack_view` fallback
    // must agree tile by tile on 1-row thin tiles — and no tile may
    // exceed the chip's image-memory capacity.
    for k in [5usize, 7] {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = k * 4; // h_max = k → 1-row tiles
        let mut g = Gen::new(0xAB0 + k as u64);
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, 2, 3 * k, 7, 0.3),
            kernels: BinaryKernels::random(&mut g, 3, 2, k),
            scale_bias: ScaleBias::random(&mut g, 3),
        };
        let jobs = decompose(&wl, &cfg);
        assert!(jobs.len() > k, "expected 1-row tiles, got {} jobs", jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            assert!(j.job.image.h <= cfg.h_max(), "tile {ji} exceeds chip capacity");
            let cyc = CycleAccurate::new(cfg).run_block(&j.job).output;
            let fun = Functional::new().run_block(&j.job).output;
            let pr1 = Functional::per_window().run_block(&j.job).output;
            assert_eq!(cyc, fun, "k={k} tile {ji} (raster pack_view)");
            assert_eq!(cyc, pr1, "k={k} tile {ji} (per-window)");
        }
    }
}

#[test]
#[should_panic(expected = "no output rows")]
fn valid_mode_thin_image_fails_loudly_instead_of_wrapping() {
    // h < k in valid mode used to underflow `h − k + 1`: a debug panic
    // deep in plan_layer, a silent usize wrap (≈2⁶⁴-row "layer") in
    // release. The geometry guard turns both into this message.
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(1);
    let wl = LayerWorkload {
        k: 5,
        zero_pad: false,
        input: random_image(&mut g, 2, 3, 8, 0.1),
        kernels: BinaryKernels::random(&mut g, 2, 2, 5),
        scale_bias: ScaleBias::random(&mut g, 2),
    };
    let _ = run_layer_engine(&wl, &cfg, ExecOptions { workers: 1 }, EngineKind::Functional);
}

#[test]
#[should_panic(expected = "h_max")]
fn h_max_smaller_than_kernel_fails_loudly_instead_of_overflowing_memory() {
    // h_max < k: the image memory cannot hold one window, yet the tiler
    // used to emit tiles of up to k > h_max input rows — silently
    // exceeding chip capacity on every engine. Now a loud precondition.
    let mut cfg = ChipConfig::tiny(4);
    cfg.image_mem_rows = 4 * 4; // h_max = 4 < k = 7
    let mut g = Gen::new(2);
    let wl = LayerWorkload {
        k: 7,
        zero_pad: true,
        input: random_image(&mut g, 2, 10, 8, 0.1),
        kernels: BinaryKernels::random(&mut g, 2, 2, 7),
        scale_bias: ScaleBias::random(&mut g, 2),
    };
    let _ = run_layer_engine(&wl, &cfg, ExecOptions { workers: 1 }, EngineKind::Functional);
}

#[test]
fn engine_raster_scratch_is_reused_across_blocks() {
    // Block-local fallback path (run_block, no layer-resident raster):
    // the engine's own scratch must also stop allocating once warm.
    let mut g = Gen::new(0x5C7B);
    let mut e = Functional::new();
    let mut job = |g: &mut Gen| BlockJob {
        k: 3,
        zero_pad: true,
        image: random_image(g, 4, 12, 10, 0.05),
        kernels: BinaryKernels::random(g, 6, 4, 3),
        scale_bias: ScaleBias::random(g, 6),
    };
    let first = job(&mut g);
    e.run_block(&first);
    let warm = e.raster_reallocs();
    for _ in 0..4 {
        let j = job(&mut g);
        e.run_block(&j);
    }
    assert_eq!(e.raster_reallocs(), warm, "same-geometry blocks must not allocate");
}
