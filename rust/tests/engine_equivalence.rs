//! The equivalence obligation of the engine refactor: the `Functional`
//! popcount engine — now running on the layer-resident bitplane raster —
//! must be **bit-identical** to the `CycleAccurate` chip simulator on
//! every supported geometry — all kernel sizes 1..=7, zero-padded and
//! valid convolutions, channel-blocked and vertically tiled layers, any
//! worker count, saturating and non-saturating amplitudes — and batched
//! inference through the serving facade (`yodann::api::Yodann`) must
//! match the layer-by-layer executor for every engine kind (including
//! the PR-1 per-window baseline kept for A/B benches and the SIMD
//! engine in both its runtime-dispatched and forced-scalar forms).

use std::sync::Arc;

use yodann::api::SessionBuilder;
use yodann::coordinator::{run_layer_engine, ExecOptions, LayerWorkload, SessionLayerSpec};
use yodann::engine::{ConvEngine, CycleAccurate, EngineKind, Functional};
use yodann::fixedpoint::Q2_9;
use yodann::hw::{BlockJob, ChipConfig};
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, Image, ScaleBias};

#[test]
fn block_level_equivalence_every_kernel_size() {
    let cfg = ChipConfig::tiny(4);
    for k in 1..=7usize {
        for zero_pad in [true, false] {
            if !zero_pad && k == 1 {
                continue; // identical to padded k=1
            }
            let mut g = Gen::new(1000 + k as u64);
            let job = BlockJob {
                k,
                zero_pad,
                image: random_image(&mut g, 3, 11, 10, 0.05),
                kernels: BinaryKernels::random(&mut g, 4, 3, k),
                scale_bias: ScaleBias::random(&mut g, 4),
            };
            let cyc = CycleAccurate::new(cfg).run_block(&job);
            let fun = Functional::new().run_block(&job);
            assert_eq!(cyc.output, fun.output, "k={k} zero_pad={zero_pad}");
        }
    }
}

#[test]
fn prop_engines_identical_on_random_blocked_tiled_layers() {
    // The central refactor property: ANY random geometry — including
    // channel blocking (n_in > n_ch), dual-mode output blocking
    // (n_out > n_ch), vertical tiling (small image_mem_rows) and
    // Q7.9-saturating amplitudes — produces bit-identical outputs on
    // both engines under any worker count.
    property("functional == cycle-accurate", 0xE9E9, 40, |g| {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * g.range(8, 24); // shrink h_max → tiling
        let k = g.range(1, 7);
        let n_in = g.range(1, 10);
        let n_out = g.range(1, 12);
        let zero_pad = g.bool();
        let h = g.range(k.max(2), 28);
        let w = g.range(k.max(2), 10);
        let amplitude = *g.choose(&[0.01, 0.05, 0.4]); // through saturation
        let wl = LayerWorkload {
            k,
            zero_pad,
            input: random_image(g, n_in, h, w, amplitude),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::random(g, n_out),
        };
        let workers = g.range(1, 4);
        let cyc = run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::CycleAccurate);
        let fun = run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::Functional);
        assert_eq!(
            cyc.output, fun.output,
            "k={k} n_in={n_in} n_out={n_out} pad={zero_pad} h={h} w={w} amp={amplitude}"
        );
        // Every other multi-bit kind — the PR-1 per-window baseline and
        // both SIMD paths (runtime-dispatched vector, forced-scalar) —
        // against the cycle-accurate reference. The binary-activation
        // family computes a different (sign) function, so it conforms
        // within itself instead: all three XNOR engines bit-identical on
        // the same workload, any geometry.
        for kind in EngineKind::MULTI_BIT {
            if matches!(kind, EngineKind::CycleAccurate | EngineKind::Functional) {
                continue;
            }
            let alt = run_layer_engine(&wl, &cfg, ExecOptions { workers }, kind);
            assert_eq!(
                cyc.output,
                alt.output,
                "{} diverges: k={k} n_in={n_in} n_out={n_out} pad={zero_pad} h={h} w={w} \
                 amp={amplitude}",
                kind.name()
            );
        }
        let xnor = run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::Xnor);
        for kind in [EngineKind::XnorSimd, EngineKind::XnorSimdScalar] {
            let alt = run_layer_engine(&wl, &cfg, ExecOptions { workers }, kind);
            assert_eq!(
                xnor.output,
                alt.output,
                "{} diverges from xnor: k={k} n_in={n_in} n_out={n_out} pad={zero_pad} h={h} \
                 w={w} amp={amplitude}",
                kind.name()
            );
        }
        assert_eq!(cyc.blocks, fun.blocks);
        assert_eq!(cyc.offchip_adds, fun.offchip_adds);
    });
}

#[test]
fn full_chip_equivalence_in_saturating_regime() {
    // Full-amplitude scene on the taped-out configuration: ChannelSummer
    // saturation fires and the input-channel saturation order must agree.
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(0x5A7E);
    let wl = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: synthetic_scene(&mut g, 64, 12, 12),
        kernels: BinaryKernels::random(&mut g, 32, 64, 3),
        scale_bias: ScaleBias::random(&mut g, 32),
    };
    let cyc = run_layer_engine(&wl, &cfg, ExecOptions::default(), EngineKind::CycleAccurate);
    let fun = run_layer_engine(&wl, &cfg, ExecOptions::default(), EngineKind::Functional);
    assert_eq!(cyc.output, fun.output);
    assert!(cyc.stats.summer_saturations > 0, "regime not saturating — weak test");
}

#[test]
fn session_batch_equals_layerwise_executor() {
    // Batched session inference (persistent pool, Arc-shared kernels,
    // zero-copy plans) vs the materializing executor, layer by layer.
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0xBA7C);
    let k1 = Arc::new(BinaryKernels::random(&mut g, 6, 3, 3));
    let k2 = Arc::new(BinaryKernels::random(&mut g, 4, 6, 5));
    let sb1 = Arc::new(ScaleBias {
        alpha: vec![Q2_9.from_f64(0.08); 6],
        beta: vec![Q2_9.from_f64(0.02); 6],
    });
    let sb2 = Arc::new(ScaleBias { alpha: vec![Q2_9.from_f64(0.1); 4], beta: vec![0; 4] });
    let specs = vec![
        SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::clone(&k1),
            scale_bias: Arc::clone(&sb1),
            relu: true,
            maxpool2: true,
        },
        SessionLayerSpec {
            k: 5,
            zero_pad: true,
            kernels: Arc::clone(&k2),
            scale_bias: Arc::clone(&sb2),
            relu: false,
            maxpool2: false,
        },
    ];
    let frames: Vec<Image> = (0..5).map(|_| synthetic_scene(&mut g, 3, 14, 12)).collect();

    // Reference: the executor path with the cycle-accurate engine.
    let reference: Vec<Image> = frames
        .iter()
        .map(|f| {
            let wl1 = LayerWorkload {
                k: 3,
                zero_pad: true,
                input: f.clone(),
                kernels: (*k1).clone(),
                scale_bias: (*sb1).clone(),
            };
            let mut x =
                run_layer_engine(&wl1, &cfg, ExecOptions { workers: 1 }, EngineKind::CycleAccurate)
                    .output;
            x.data.iter_mut().for_each(|v| *v = (*v).max(0));
            // 2x2 max-pool, stride 2.
            let mut p = Image::zeros(x.c, x.h / 2, x.w / 2);
            for c in 0..p.c {
                for y in 0..p.h {
                    for xx in 0..p.w {
                        *p.at_mut(c, y, xx) = x
                            .at(c, 2 * y, 2 * xx)
                            .max(x.at(c, 2 * y, 2 * xx + 1))
                            .max(x.at(c, 2 * y + 1, 2 * xx))
                            .max(x.at(c, 2 * y + 1, 2 * xx + 1));
                    }
                }
            }
            let wl2 = LayerWorkload {
                k: 5,
                zero_pad: true,
                input: p,
                kernels: (*k2).clone(),
                scale_bias: (*sb2).clone(),
            };
            run_layer_engine(&wl2, &cfg, ExecOptions { workers: 1 }, EngineKind::CycleAccurate)
                .output
        })
        .collect();

    let session_batch = |kind: EngineKind| -> Vec<Image> {
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(kind)
            .workers(3)
            .max_in_flight(frames.len())
            .build()
            .expect("two-layer chain is valid");
        sess.run_batch(frames.clone()).expect("batch runs").into_iter().map(|r| r.output).collect()
    };
    for kind in EngineKind::MULTI_BIT {
        assert_eq!(session_batch(kind), reference, "engine {}", kind.name());
    }
    // The binary family runs the same chain as a BNN (sign activations):
    // different numbers than the Q2.9 reference by design, but the three
    // XNOR engines must agree with each other batch-for-batch.
    let xnor_reference = session_batch(EngineKind::Xnor);
    for kind in [EngineKind::XnorSimd, EngineKind::XnorSimdScalar] {
        assert_eq!(session_batch(kind), xnor_reference, "engine {}", kind.name());
    }
}

#[test]
fn worker_count_never_changes_results() {
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0x333);
    let wl = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: random_image(&mut g, 9, 20, 8, 0.05),
        kernels: BinaryKernels::random(&mut g, 10, 9, 3),
        scale_bias: ScaleBias::random(&mut g, 10),
    };
    let base = run_layer_engine(&wl, &cfg, ExecOptions { workers: 1 }, EngineKind::Functional);
    for workers in [2, 3, 8] {
        let r = run_layer_engine(&wl, &cfg, ExecOptions { workers }, EngineKind::Functional);
        assert_eq!(r.output, base.output, "workers={workers}");
    }
}
