//! The serving API's behavioral contract: typed errors on every former
//! panic path, edge-case batches, ticket lifecycle (including after the
//! session is gone), backpressure, and per-frame telemetry — everything
//! ISSUE 4 promises the facade over and above the raw coordinator.

use std::sync::Arc;

use yodann::api::{SessionBuilder, YodannError};
use yodann::coordinator::{SessionLayerSpec, ShardGrid, ShardPolicy};
use yodann::engine::EngineKind;
use yodann::hw::ChipConfig;
use yodann::model::layer::{DenseLayer, Layer};
use yodann::model::{networks, Network};
use yodann::testkit::Gen;
use yodann::workload::{random_image, reference_conv, BinaryKernels, Image, ScaleBias};

fn one_layer(k: usize, n_in: usize, n_out: usize, zero_pad: bool, seed: u64) -> SessionLayerSpec {
    let mut g = Gen::new(seed);
    SessionLayerSpec {
        k,
        zero_pad,
        kernels: Arc::new(BinaryKernels::random(&mut g, n_out, n_in, k)),
        scale_bias: Arc::new(ScaleBias::random(&mut g, n_out)),
        relu: false,
        maxpool2: false,
    }
}

#[test]
fn empty_batch_is_ok_and_empty() {
    let mut sess = SessionBuilder::new()
        .layers(vec![one_layer(3, 3, 4, true, 1)])
        .workers(2)
        .build()
        .unwrap();
    let out = sess.run_batch(Vec::new()).unwrap();
    assert!(out.is_empty());
    assert_eq!(sess.in_flight(), 0);
}

#[test]
fn one_by_one_frames_serve_and_match_the_reference() {
    // A 1×1 zero-padded frame is a legal (if degenerate) request: one
    // output pixel per channel, bit-identical to the reference conv.
    let spec = one_layer(3, 3, 5, true, 2);
    let kernels = Arc::clone(&spec.kernels);
    let sb = Arc::clone(&spec.scale_bias);
    let mut sess = SessionBuilder::new().layers(vec![spec]).workers(1).build().unwrap();
    let mut g = Gen::new(3);
    let frame = random_image(&mut g, 3, 1, 1, 0.1);
    let want = reference_conv(&frame, &kernels, &sb, true);
    let got = sess.submit(frame).unwrap().wait().unwrap();
    assert_eq!((got.output.c, got.output.h, got.output.w), (5, 1, 1));
    assert_eq!(got.output, want);
}

#[test]
fn mismatched_geometry_is_a_typed_error_not_a_panic() {
    // Valid-mode k=5 over a 3-row frame: pre-redesign this panicked in a
    // worker (debug) or wrapped a usize (release). Now: a typed error,
    // the frame never enters the queue, and the session stays usable.
    let mut sess = SessionBuilder::new()
        .layers(vec![one_layer(5, 2, 3, false, 4)])
        .workers(1)
        .build()
        .unwrap();
    let err = sess.submit(Image::zeros(2, 3, 9)).unwrap_err();
    assert!(
        matches!(&err, YodannError::AtLayer { layer: 0, inner }
            if matches!(**inner, YodannError::NoOutputRows { k: 5, axis: "height", size: 3 })),
        "{err}"
    );
    // Channel mismatch likewise.
    let err = sess.submit(Image::zeros(7, 9, 9)).unwrap_err();
    assert_eq!(err, YodannError::FrameChannelMismatch { got: 7, expected: 2 });
    // And a well-formed frame still serves.
    let ok = sess.submit(Image::zeros(2, 9, 9)).unwrap().wait().unwrap();
    assert_eq!((ok.output.h, ok.output.w), (5, 5));
}

#[test]
fn tickets_survive_session_drop() {
    // Dropping the session drains in-flight frames before the
    // dispatcher exits; an outstanding ticket still redeems.
    let mut sess = SessionBuilder::new()
        .layers(vec![one_layer(3, 3, 4, true, 5)])
        .workers(2)
        .build()
        .unwrap();
    let mut g = Gen::new(6);
    let frame = random_image(&mut g, 3, 10, 10, 0.05);
    let mut ticket = sess.submit(frame).unwrap();
    drop(sess);
    assert!(ticket.poll(), "result must be delivered by the draining dispatcher");
    let res = ticket.wait().unwrap();
    assert_eq!(res.frame_id, 0);
    assert_eq!((res.output.c, res.output.h, res.output.w), (4, 10, 10));
}

#[test]
fn backpressure_is_reported_and_recoverable() {
    let mut sess = SessionBuilder::new()
        .layers(vec![one_layer(3, 2, 2, true, 7)])
        .workers(1)
        .max_in_flight(2)
        .build()
        .unwrap();
    let mut g = Gen::new(8);
    let frames: Vec<Image> = (0..3).map(|_| random_image(&mut g, 2, 8, 8, 0.05)).collect();
    let t0 = sess.submit(frames[0].clone()).unwrap();
    let _t1 = sess.submit(frames[1].clone()).unwrap();
    assert_eq!(sess.in_flight(), 2);
    let err = sess.submit(frames[2].clone()).unwrap_err();
    assert_eq!(err, YodannError::Backpressure { in_flight: 2, limit: 2 });
    // Draining one ticket frees one slot.
    t0.wait().unwrap();
    let t2 = sess.submit(frames[2].clone()).unwrap();
    assert!(t2.wait().is_ok());
}

#[test]
fn run_batch_pipelines_past_the_in_flight_bound() {
    // 6 frames through a bound of 2: the convenience loop must drain
    // as it goes and return everything in input order.
    let specs = vec![one_layer(3, 3, 4, true, 9)];
    let mut g = Gen::new(10);
    let frames: Vec<Image> = (0..6).map(|_| random_image(&mut g, 3, 9, 9, 0.05)).collect();
    let mut bounded = SessionBuilder::new()
        .layers(specs.clone())
        .workers(2)
        .max_in_flight(2)
        .build()
        .unwrap();
    let got = bounded.run_batch(frames.clone()).unwrap();
    assert_eq!(got.len(), 6);
    assert_eq!(sess_ids(&got), vec![0, 1, 2, 3, 4, 5]);
    // Same answers as an unbounded session.
    let mut roomy = SessionBuilder::new()
        .layers(specs)
        .workers(2)
        .max_in_flight(16)
        .build()
        .unwrap();
    let want = roomy.run_batch(frames).unwrap();
    for (g, w) in got.iter().zip(want.iter()) {
        assert_eq!(g.output, w.output);
    }
}

fn sess_ids(rs: &[yodann::api::FrameResult]) -> Vec<u64> {
    rs.iter().map(|r| r.frame_id).collect()
}

#[test]
fn telemetry_rides_on_every_result() {
    let specs = vec![one_layer(3, 3, 4, true, 11)];
    let mut g = Gen::new(12);
    let frame = random_image(&mut g, 3, 12, 12, 0.05);
    // Cycle-accurate: full ledger, priced metrics.
    let mut cyc = SessionBuilder::new()
        .layers(specs.clone())
        .engine(EngineKind::CycleAccurate)
        .workers(1)
        .supply(0.6)
        .build()
        .unwrap();
    let r = cyc.submit(frame.clone()).unwrap().wait().unwrap();
    let t = &r.telemetry;
    assert_eq!(t.engine, EngineKind::CycleAccurate);
    assert!(t.cycles > 0 && t.ops > 0);
    let m = t.metrics.as_ref().expect("cycle engine prices its frames");
    assert!(m.time > 0.0 && m.theta > 0.0);
    assert!(t.energy_j().unwrap() > 0.0);
    assert!(t.chip_gops().unwrap() > 0.0);
    assert!((t.corner.v - 0.6).abs() < 1e-12);
    assert!(t.envelope.total_w() > 0.0);
    // Functional: ops only — same Eq. 7 count, no cycle ledger, no
    // fabricated metrics.
    let mut fun = SessionBuilder::new()
        .layers(specs)
        .engine(EngineKind::Functional)
        .workers(1)
        .build()
        .unwrap();
    let rf = fun.submit(frame).unwrap().wait().unwrap();
    assert_eq!(rf.telemetry.ops, t.ops, "Eq. 7 accounting must not depend on the engine");
    assert_eq!(rf.telemetry.cycles, 0);
    assert!(rf.telemetry.metrics.is_none());
    // The two engines also agree on the image, of course.
    assert_eq!(rf.output, r.output);
}

#[test]
fn per_shard_sessions_report_the_grid_envelope() {
    let mut grid4 = SessionBuilder::new()
        .layers(vec![one_layer(3, 3, 4, true, 13)])
        .shard_policy(ShardPolicy::PerShard(ShardGrid::new(2, 2)))
        .workers(2)
        .build()
        .unwrap();
    let mut g = Gen::new(14);
    let r = grid4.submit(random_image(&mut g, 3, 10, 10, 0.05)).unwrap().wait().unwrap();
    assert_eq!(r.telemetry.policy, ShardPolicy::PerShard(ShardGrid::new(2, 2)));
    assert_eq!(r.telemetry.envelope.chips, 4);
    // 4 chips burn 4x one chip's envelope.
    let one_chip = r.telemetry.envelope.core_w_each + r.telemetry.envelope.io_w_each;
    assert!((r.telemetry.envelope.total_w() - 4.0 * one_chip).abs() < 1e-12);
}

#[test]
fn synthetic_network_rejects_unknown_layer_kinds_typed() {
    // A descriptor with no conv rows at all — only a host-side dense
    // layer the accelerator cannot schedule — must come back as a typed
    // NoConvLayers, not a stringly error (regression for the
    // unknown-layer-kind spec path).
    let dense_only = Network {
        id: "dense-only",
        name: "DenseOnly",
        img: (8, 8),
        layers: vec![Layer::Dense(DenseLayer { label: "fc", n_in: 64, n_out: 10, repeat: 1 })],
    };
    let err = SessionLayerSpec::synthetic_network(&dense_only, 1).unwrap_err();
    assert_eq!(err, YodannError::NoConvLayers { net: "dense-only".into() });
    // Through the builder, the same spec fails at build — eagerly.
    let err = SessionBuilder::new().network(&dense_only, 1).build().unwrap_err();
    assert_eq!(err, YodannError::NoConvLayers { net: "dense-only".into() });
    // And the non-chain network keeps its typed rejection.
    let err = SessionBuilder::new().network(&networks::alexnet(), 1).build().unwrap_err();
    assert!(matches!(err, YodannError::NotASimpleChain { .. }));
}

#[test]
fn builder_rejects_chip_capacity_violations_eagerly() {
    // h_max < k used to panic deep in the planner on the first frame;
    // the builder refuses at build time, naming the layer.
    let mut cfg = ChipConfig::tiny(4);
    cfg.image_mem_rows = 4 * 4; // h_max = 4 < k = 7
    let err = SessionBuilder::new()
        .chip(cfg)
        .layers(vec![one_layer(7, 2, 2, true, 15)])
        .build()
        .unwrap_err();
    assert!(
        matches!(&err, YodannError::AtLayer { layer: 0, inner }
            if matches!(**inner, YodannError::ChipCapacity { k: 7, h_max: 4, .. })),
        "{err}"
    );
}
