//! The BNN subsystem's acceptance gates (ISSUE 10): fuzzed XNOR
//! conformance — seeded geometries × kernel sizes {1, 2, 3, 5, 7} ×
//! shard policies × every binary engine (and the multi-bit engines'
//! binary companions, reached through the per-layer `Precision` knob) —
//! bit-identical to the naive sign reference through the serving
//! facade's submit/poll surface; a mixed-precision BWN-stem → BNN-trunk
//! chain served end-to-end against a host-composed reference, with the
//! activation-traffic reduction the 1-plane sign raster buys; the
//! CLI-spelling round-trips for `EngineKind`, `ShardPolicy` and
//! `Precision`; and the near-threshold bit-error-rate curve the fault
//! sweeps price binary corners with.

use std::sync::Arc;

use yodann::api::SessionBuilder;
use yodann::coordinator::{SessionLayerSpec, ShardGrid, ShardPolicy};
use yodann::engine::EngineKind;
use yodann::fault::{self, FaultPlan};
use yodann::hw::ChipConfig;
use yodann::model::{Corner, Precision};
use yodann::power::xnor::{activation_words, ACTIVATION_PLANES_BWN, ACTIVATION_PLANES_XNOR};
use yodann::power::{ArchId, CorePowerModel};
use yodann::testkit::{property, Gen};
use yodann::workload::{
    random_image, reference_conv, reference_xnor_conv, BinaryKernels, Image, ScaleBias,
};

#[test]
fn prop_xnor_sessions_match_the_sign_reference_under_every_schedule() {
    // The central conformance property: ANY random single-block geometry
    // (n_in ≤ n_ch keeps the monolithic reference's Q7.9 accumulation
    // order exact), any kernel size, any shard policy, any binary
    // engine — whether selected directly or routed as a multi-bit
    // engine's companion via `Precision::Binary` — serves frames
    // bit-identical to the naive sign reference.
    property("session xnor == sign reference", 0x0B1A5, 40, |g| {
        let k = *g.choose(&[1usize, 2, 3, 5, 7]);
        let n_ch = g.range(2, 6);
        let cfg = ChipConfig::tiny(n_ch);
        let n_in = g.range(1, n_ch); // single input block
        let n_out = g.range(1, 2 * n_ch); // straddles the output block limit
        let zero_pad = g.bool() || k == 1; // valid k=1 is identical to padded
        let h = g.range(k.max(2), 14);
        let w = g.range(k.max(2), 10);
        let amplitude = *g.choose(&[0.05, 0.4]);
        let image = random_image(g, n_in, h, w, amplitude);
        let kernels = BinaryKernels::random(g, n_out, n_in, k);
        let sb = ScaleBias::random(g, n_out);
        let want = reference_xnor_conv(&image, &kernels, &sb, zero_pad);
        let policy = match g.range(0, 3) {
            0 => ShardPolicy::PerFrame,
            1 => ShardPolicy::Auto,
            2 => ShardPolicy::RowBands(g.range(1, 3)),
            _ => ShardPolicy::PerShard(ShardGrid::new(g.range(1, 3), g.range(1, 2))),
        };
        let workers = g.range(1, 3);
        let spec = SessionLayerSpec {
            k,
            zero_pad,
            kernels: Arc::new(kernels),
            scale_bias: Arc::new(sb),
            relu: false,
            maxpool2: false,
        };
        let (kind, precision) = if g.bool() {
            (*g.choose(&EngineKind::XNOR), None)
        } else {
            // The companion route: a multi-bit main engine whose only
            // layer is binary runs that layer on `kind.binary_companion()`.
            (*g.choose(&EngineKind::MULTI_BIT), Some(vec![Precision::Binary]))
        };
        let ctx = format!(
            "k={k} kind={} policy={policy} {n_in}->{n_out} {h}x{w} pad={zero_pad} \
             amp={amplitude} workers={workers} companion={}",
            kind.name(),
            precision.is_some(),
        );
        let mut builder = SessionBuilder::new()
            .chip(cfg)
            .layers(vec![spec])
            .engine(kind)
            .workers(workers)
            .shard_policy(policy)
            .fault_plan(FaultPlan::disabled());
        if let Some(ps) = precision {
            builder = builder.precision(ps);
        }
        let mut sess = builder.build().unwrap_or_else(|e| panic!("build ({ctx}): {e}"));
        // Through the non-blocking surface on purpose: poll to
        // completion, then redeem.
        let mut ticket = sess.submit(image).expect("frame admits");
        while !ticket.poll() {
            std::thread::yield_now();
        }
        let got = ticket.wait().expect("frame computes").output;
        assert_eq!(got, want, "{ctx}");
    });
}

#[test]
fn mixed_precision_chain_serves_end_to_end_and_cuts_activation_traffic() {
    // The acceptance chain: a multi-bit BWN stem feeding a binary BNN
    // trunk, served through submit/poll, bit-identical to the
    // host-composed reference (Q2.9 conv for the stem, the sign
    // reference for each trunk layer) — and the trunk's activation
    // traffic shrinks 12× per layer, 1 sign plane vs 12 offset-binary
    // bitplanes.
    let cfg = ChipConfig::tiny(8);
    let mut g = Gen::new(0x317D);
    let (h, w) = (10usize, 9usize);
    let mut mk = |n_out: usize, n_in: usize| SessionLayerSpec {
        k: 3,
        zero_pad: true,
        kernels: Arc::new(BinaryKernels::random(&mut g, n_out, n_in, 3)),
        scale_bias: Arc::new(ScaleBias::random(&mut g, n_out)),
        relu: false,
        maxpool2: false,
    };
    let specs = vec![mk(8, 3), mk(8, 8), mk(6, 8)];
    let frames: Vec<Image> = (0..3).map(|_| random_image(&mut g, 3, h, w, 0.3)).collect();
    let precision = vec![Precision::MultiBit, Precision::Binary, Precision::Binary];

    let serve = |kind: EngineKind, ps: Option<Vec<Precision>>| -> (f64, Vec<Image>) {
        let mut builder = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(kind)
            .workers(2)
            .max_in_flight(frames.len())
            .fault_plan(FaultPlan::disabled());
        if let Some(ps) = ps {
            builder = builder.precision(ps);
        }
        let mut sess = builder.build().expect("mixed-precision chain builds");
        let frac = sess.binary_layer_fraction();
        let mut tickets: Vec<_> =
            frames.iter().map(|f| sess.submit(f.clone()).expect("admits")).collect();
        while !tickets.iter_mut().all(|t| t.poll()) {
            std::thread::yield_now();
        }
        (frac, tickets.into_iter().map(|t| t.wait().expect("computes").output).collect())
    };

    let (frac_bwn, bwn) = serve(EngineKind::FunctionalSimd, None);
    let (frac_mixed, mixed) = serve(EngineKind::FunctionalSimd, Some(precision.clone()));
    assert_eq!(frac_bwn, 0.0);
    assert!((frac_mixed - 2.0 / 3.0).abs() < 1e-12, "fraction {frac_mixed}");

    for (i, (f, got)) in frames.iter().zip(&mixed).enumerate() {
        let s0 = reference_conv(f, &specs[0].kernels, &specs[0].scale_bias, true);
        let s1 = reference_xnor_conv(&s0, &specs[1].kernels, &specs[1].scale_bias, true);
        let want = reference_xnor_conv(&s1, &specs[2].kernels, &specs[2].scale_bias, true);
        assert_eq!(*got, want, "frame {i}");
    }
    assert_ne!(mixed, bwn, "the binary trunk must change the numbers");

    // Companion routing is engine-agnostic: the scalar functional main
    // engine must binarize the same layers to the same bits as the SIMD
    // one (Xnor vs XnorSimd companions).
    let (_, mixed_scalar) = serve(EngineKind::Functional, Some(precision.clone()));
    assert_eq!(mixed_scalar, mixed);

    // The reported traffic: per conv layer, input activation words at
    // that layer's precision.
    let per_layer = |p: Precision, c: usize| {
        let planes = match p {
            Precision::MultiBit => ACTIVATION_PLANES_BWN,
            Precision::Binary => ACTIVATION_PLANES_XNOR,
        };
        activation_words(c, h, w, 3, true, planes)
    };
    let chans = [3usize, 8, 8]; // each layer's input channels
    let all_bwn: usize = chans.iter().map(|&c| per_layer(Precision::MultiBit, c)).sum();
    let mixed_words: usize = chans.iter().zip(&precision).map(|(&c, &p)| per_layer(p, c)).sum();
    assert!(mixed_words < all_bwn, "{mixed_words} !< {all_bwn}");
    assert_eq!(
        per_layer(Precision::MultiBit, 8),
        12 * per_layer(Precision::Binary, 8),
        "one binary trunk layer moves 12x fewer words"
    );
}

#[test]
fn accepted_spellings_parse_and_canonical_names_round_trip() {
    // Drift pins: every spelling each ACCEPTED list advertises parses,
    // every canonical name/Display form re-parses to the same value —
    // so `--engine`, `--shards` and `--precision` error messages can
    // echo the lists verbatim.
    for s in EngineKind::ACCEPTED {
        let kind = EngineKind::parse(s)
            .unwrap_or_else(|| panic!("accepted engine spelling {s:?} must parse"));
        assert!(EngineKind::ALL.contains(&kind), "{s} parses outside ALL");
        assert_eq!(EngineKind::parse(&s.to_uppercase()), Some(kind), "case-insensitive {s}");
    }
    for kind in EngineKind::ALL {
        assert_eq!(EngineKind::parse(kind.name()), Some(kind), "{}", kind.name());
        assert!(EngineKind::ACCEPTED.contains(&kind.name()), "{} not accepted", kind.name());
    }
    // The binary family's aliases specifically.
    assert_eq!(EngineKind::parse("bnn"), Some(EngineKind::Xnor));
    assert_eq!(EngineKind::parse("xnor-simd"), Some(EngineKind::XnorSimd));
    assert_eq!(EngineKind::parse("xnor-simd-scalar"), Some(EngineKind::XnorSimdScalar));

    for s in ShardPolicy::ACCEPTED {
        let p = ShardPolicy::parse(s)
            .unwrap_or_else(|| panic!("accepted shard spelling {s:?} must parse"));
        assert_eq!(ShardPolicy::parse(&p.to_string()), Some(p), "{s} display re-parses");
    }
    for s in Precision::ACCEPTED {
        let p = Precision::parse(s)
            .unwrap_or_else(|| panic!("accepted precision spelling {s:?} must parse"));
        assert!(Precision::ALL.contains(&p), "{s} parses outside ALL");
        assert_eq!(Precision::parse(p.name()), Some(p), "{s} name re-parses");
        assert_eq!(p.to_string(), p.name(), "Display echoes the canonical name");
    }
}

#[test]
fn prop_shard_policy_display_parse_round_trips() {
    // Beyond the fixed ACCEPTED spellings: every constructible policy —
    // including `row-bands:N` for arbitrary N and `per-shard:NxM` grids
    // — survives a Display → parse round trip.
    property("shard policy display/parse", 0x5A4D, 200, |g| {
        let p = match g.range(0, 3) {
            0 => ShardPolicy::PerFrame,
            1 => ShardPolicy::Auto,
            2 => ShardPolicy::RowBands(g.range(0, 64)),
            _ => ShardPolicy::PerShard(ShardGrid::new(g.range(1, 40), g.range(1, 40))),
        };
        assert_eq!(ShardPolicy::parse(&p.to_string()), Some(p), "{p}");
    });
}

#[test]
fn bit_error_rate_is_monotone_in_supply_and_matches_the_fitted_curve() {
    // The near-threshold contract behind `yodann faults`: raising the
    // supply never raises the memory upset rate, `fault::bit_error_rate`
    // is exactly the architecture's fitted curve at the corner's
    // voltage, and off-range corners saturate instead of panicking.
    let arches = [ArchId::Bin8, ArchId::Bin16, ArchId::Bin32Fixed, ArchId::Bin32Multi];
    for arch in arches {
        let vf = CorePowerModel::new(arch).vf;
        let steps = 64;
        let mut prev = f64::INFINITY;
        for i in 0..=steps {
            let v = vf.vmin + (vf.vmax - vf.vmin) * i as f64 / steps as f64;
            let ber = fault::bit_error_rate(Corner { arch, v });
            assert!(ber == vf.bit_error_rate(v), "{arch:?} v={v}: corner/curve drift");
            assert!(ber > 0.0 && ber <= 1e-2, "{arch:?} v={v}: {ber} out of range");
            assert!(ber <= prev, "{arch:?}: BER rose {prev} -> {ber} at v={v}");
            prev = ber;
        }
        // The nominal rail sits at the 1e-9 baseline; the serve/fault
        // pricing corners evaluate the same curve.
        assert!((vf.bit_error_rate(vf.vmax) - 1e-9).abs() < 1e-15);
        for v in [0.6, 0.8, 1.0, 1.2] {
            assert!(fault::bit_error_rate(Corner { arch, v }) == vf.bit_error_rate(v));
        }
        // Below the fitted threshold the margin clamps to zero: a
        // constant saturated rate, never a panic, never above the cap.
        let floor = vf.bit_error_rate(vf.vt);
        assert!(vf.bit_error_rate(0.0) == floor);
        assert!(vf.bit_error_rate(-1.0) == floor);
        assert!(floor <= 1e-2);
        // Far above the rail clamps to the nominal baseline.
        assert!(vf.bit_error_rate(10.0) == 1e-9);
    }
    property("BER non-increasing in V", 0x0BE4, 300, |g| {
        let arch = *g.choose(&arches);
        let vf = CorePowerModel::new(arch).vf;
        let a = g.f64_in(0.0, 1.5);
        let b = g.f64_in(0.0, 1.5);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(
            vf.bit_error_rate(lo) >= vf.bit_error_rate(hi),
            "{arch:?}: BER({lo}) < BER({hi})"
        );
    });
}
