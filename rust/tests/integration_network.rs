//! Integration: multi-layer networks on the coordinator + simulator —
//! channel blocking, vertical tiling, off-chip accumulation and the
//! quantized inter-layer plumbing (ReLU, max-pool) all composed.

use yodann::coordinator::{run_layer, ExecOptions, LayerWorkload};
use yodann::fixedpoint;
use yodann::hw::ChipConfig;
use yodann::testkit::Gen;
use yodann::workload::{
    random_image, reference_conv, synthetic_scene, BinaryKernels, Image, ScaleBias,
};

fn relu(img: &mut Image) {
    for v in img.data.iter_mut() {
        *v = (*v).max(0);
    }
}

fn maxpool2(img: &Image) -> Image {
    let mut out = Image::zeros(img.c, img.h / 2, img.w / 2);
    for c in 0..img.c {
        for y in 0..img.h / 2 {
            for x in 0..img.w / 2 {
                let m = img
                    .at(c, 2 * y, 2 * x)
                    .max(img.at(c, 2 * y, 2 * x + 1))
                    .max(img.at(c, 2 * y + 1, 2 * x))
                    .max(img.at(c, 2 * y + 1, 2 * x + 1));
                *out.at_mut(c, y, x) = m;
            }
        }
    }
    out
}

/// A BC-Cifar-10-shaped (scaled-down) network run end to end on the
/// simulated chip, checked layer-by-layer against the blocked reference.
#[test]
fn three_layer_cnn_end_to_end() {
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(2024);
    let mut x = synthetic_scene(&mut g, 3, 16, 16);
    // Keep activations small so blocked == monolithic reference.
    for v in x.data.iter_mut() {
        *v /= 16;
    }
    let widths = [3usize, 48, 64, 8];
    for li in 0..3 {
        let (n_in, n_out) = (widths[li], widths[li + 1]);
        let kernels = BinaryKernels::random(&mut g, n_out, n_in, 3);
        // Small scales keep the dynamic range contained layer to layer.
        let sb = ScaleBias {
            alpha: vec![fixedpoint::Q2_9.from_f64(0.05); n_out],
            beta: vec![0; n_out],
        };
        let wl = LayerWorkload { k: 3, zero_pad: true, input: x.clone(), kernels, scale_bias: sb };
        let run = run_layer(&wl, &cfg, ExecOptions::default());
        let want = reference_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
        assert_eq!(run.output, want, "layer {li}");
        x = run.output;
        relu(&mut x);
        if li == 0 {
            x = maxpool2(&x);
        }
    }
    assert_eq!((x.c, x.h, x.w), (8, 8, 8));
}

#[test]
fn blocked_layer_uses_expected_block_count() {
    // 128→128 3×3 (dual mode): 4 in-blocks × 2 out-blocks = 8 jobs.
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(7);
    let wl = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: random_image(&mut g, 128, 16, 16, 0.01),
        kernels: BinaryKernels::random(&mut g, 128, 128, 3),
        scale_bias: ScaleBias::random(&mut g, 128),
    };
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    assert_eq!(run.blocks, 8);
    // Off-chip additions: 3 extra adds per output pixel (4 input blocks).
    assert_eq!(run.offchip_adds, 3 * 128 * 16 * 16);
    // The paper's claim: only ⌈n_in/n_ch⌉−1 extra ops per output pixel.
    let per_pixel = run.offchip_adds as f64 / (128.0 * 16.0 * 16.0);
    assert_eq!(per_pixel, 3.0);
}

#[test]
fn blocked_equals_monolithic_when_not_saturating() {
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(99);
    let wl = LayerWorkload {
        k: 5,
        zero_pad: true,
        input: random_image(&mut g, 64, 20, 12, 0.01),
        kernels: BinaryKernels::random(&mut g, 96, 64, 5),
        scale_bias: ScaleBias::random(&mut g, 96),
    };
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    let want = reference_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
    assert_eq!(run.output, want);
}

#[test]
fn blocked_saturation_divergence_is_bounded() {
    // In the saturating regime blocked partials clip at Q2.9 per block;
    // quantify the divergence vs the monolithic reference (an inherent
    // property of the paper's off-chip accumulation scheme).
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(4242);
    let wl = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: synthetic_scene(&mut g, 64, 12, 12),
        kernels: BinaryKernels::random(&mut g, 32, 64, 3),
        scale_bias: ScaleBias { alpha: vec![64; 32], beta: vec![0; 32] },
    };
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    let mono = reference_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
    let max_dev = run
        .output
        .data
        .iter()
        .zip(mono.data.iter())
        .map(|(a, b)| (a - b).abs())
        .max()
        .unwrap();
    // Bounded by the per-block clip range times the scale.
    assert!(max_dev <= 2048, "divergence {max_dev} raw LSBs");
}

#[test]
fn simulated_cycles_scale_with_blocks() {
    let cfg = ChipConfig::yodann();
    let mut g = Gen::new(314);
    let small = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: random_image(&mut g, 32, 16, 16, 0.01),
        kernels: BinaryKernels::random(&mut g, 64, 32, 3),
        scale_bias: ScaleBias::identity(64),
    };
    let big = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: random_image(&mut g, 64, 16, 16, 0.01),
        kernels: BinaryKernels::random(&mut g, 64, 64, 3),
        scale_bias: ScaleBias::identity(64),
    };
    let a = run_layer(&small, &cfg, ExecOptions::default());
    let b = run_layer(&big, &cfg, ExecOptions::default());
    // Twice the input channels → two input blocks → ≈2× compute cycles.
    let ratio = b.stats.cycles.compute as f64 / a.stats.cycles.compute as f64;
    assert!((ratio - 2.0).abs() < 0.05, "{ratio}");
}
