//! Cross-validation: the analytic efficiency model (Eqs. 8–11, which
//! regenerates the paper's Tables III–V) vs the cycle-accurate simulator.
//! The two are independent derivations of the same microarchitecture; on
//! fully-specified workloads they must agree.

use yodann::coordinator::{metrics::sim_metrics, run_layer, ExecOptions, LayerWorkload};
use yodann::hw::{ChipConfig, EnergyModel};
use yodann::model::efficiency::{eta_ch_idle, eta_tile};
use yodann::power::{ArchId, CorePowerModel};
use yodann::testkit::Gen;
use yodann::workload::{random_image, BinaryKernels, ScaleBias};

fn workload(k: usize, n_in: usize, n_out: usize, h: usize, w: usize) -> LayerWorkload {
    let mut g = Gen::new((k * 1000 + n_in * 10 + n_out) as u64);
    LayerWorkload {
        k,
        zero_pad: true,
        input: random_image(&mut g, n_in, h, w, 0.01),
        kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
        scale_bias: ScaleBias::identity(n_out),
    }
}

/// Simulated steady-state throughput ≈ Θ_peak · η_chIdle (filter-load and
/// preload amortize out on larger tiles).
#[test]
fn simulated_throughput_matches_eq10() {
    let cfg = ChipConfig::yodann();
    let core = CorePowerModel::new(ArchId::Bin32Multi);
    for (n_in, n_out) in [(32usize, 64usize), (16, 64), (8, 64)] {
        let wl = workload(3, n_in, n_out, 32, 32);
        let run = run_layer(&wl, &cfg, ExecOptions::default());
        let m = sim_metrics(&run.stats, ArchId::Bin32Multi, 0.6, true);
        let analytic = core.theta_peak(0.6, 3) * eta_ch_idle(n_in, 32);
        let rel = (m.theta - analytic).abs() / analytic;
        // Within 12%: the residual is the un-amortized filter load +
        // preload on this small tile.
        assert!(rel < 0.12, "n_in={n_in}: sim {} vs analytic {analytic}", m.theta);
    }
}

/// Simulated energy efficiency at full utilization lands on the paper's
/// per-mode numbers (Table III rows: 59.2 TOp/s/W for 3×3 at 0.6 V).
#[test]
fn simulated_en_eff_matches_table3_mode_rows() {
    let cfg = ChipConfig::yodann();
    let wl = workload(3, 32, 64, 32, 32);
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    let em = EnergyModel::new(ArchId::Bin32Multi, 0.6);
    let en_eff = em.en_eff(&run.stats) / 1e12;
    // The event-level energy model is calibrated on the 7×7 breakdown;
    // its 3×3 estimate must land in the right regime (the paper: 59.2).
    assert!((35.0..75.0).contains(&en_eff), "{en_eff} TOp/s/W");
}

/// 7×7 full-utilization: simulator vs the 61.2 TOp/s/W headline. A wide
/// tile amortizes the filter-load and column-preload phases the paper's
/// *peak* numbers exclude; the residual gap is exactly those phases.
#[test]
fn simulated_en_eff_matches_headline_7x7() {
    let cfg = ChipConfig::yodann();
    let wl = workload(7, 32, 32, 32, 96);
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    let em = EnergyModel::new(ArchId::Bin32Multi, 0.6);
    let en_eff = em.en_eff(&run.stats) / 1e12;
    assert!(
        (en_eff - 61.2).abs() / 61.2 < 0.06,
        "simulated {en_eff} vs paper 61.2 TOp/s/W"
    );
    let m = sim_metrics(&run.stats, ArchId::Bin32Multi, 0.6, false);
    assert!((m.theta / 1e9 - 55.0).abs() / 55.0 < 0.10, "{} GOp/s", m.theta / 1e9);
}

/// Tiling: the simulated re-load overhead of vertical tiling brackets
/// Eq. 9's η_tile. Interesting reproduction finding (EXPERIMENTS.md):
/// Eq. 9 counts `⌈h/h_max⌉` tiles, but a tile holding `h_max` *input*
/// rows only produces `h_max − k + 1` output rows, so the implementable
/// schedule needs slightly more tiles than the paper's formula — the
/// simulator measures the real overhead, which must lie between Eq. 9's
/// optimistic value and the output-row-tiling bound.
#[test]
fn simulated_tiling_overhead_matches_eq9() {
    let mut cfg = ChipConfig::yodann();
    cfg.image_mem_rows = 16 * 32; // h_max = 16
    let k = 7;
    let (h, w, n_in) = (40usize, 8usize, 8usize);
    // Tiles: output rows 10+10+10+10, input heights 13/16/16/13 = 58 rows.
    let wl = workload(k, n_in, 8, h, w);
    let run = run_layer(&wl, &cfg, ExecOptions::default());
    // Every tile pixel is written to SCM exactly once.
    let overhead = run.stats.scm_writes as f64 / (n_in * h * w) as f64;
    assert_eq!(run.stats.scm_writes, (n_in * 58 * w) as u64);
    let eq9 = 1.0 / eta_tile(h, 16, k); // 1.30 (optimistic)
    let real_bound = (h as f64 + 3.0 * (k - 1) as f64) / h as f64; // 1.45
    assert!(overhead >= eq9 - 1e-9, "{overhead} < Eq.9 {eq9}");
    assert!(overhead <= real_bound + 1e-9, "{overhead} > bound {real_bound}");
}

/// The SCM gating bound holds on every workload: ≤ 7 banks/cycle.
#[test]
fn scm_gating_bound_universal() {
    let cfg = ChipConfig::yodann();
    for k in [1usize, 3, 5, 7] {
        let wl = workload(k, 32, 32, 16, 12);
        let run = run_layer(&wl, &cfg, ExecOptions::default());
        assert!(
            run.stats.scm_max_banks_per_cycle <= 7,
            "k={k}: {} banks",
            run.stats.scm_max_banks_per_cycle
        );
    }
}

/// Input-stream invariant: at most one 12-bit word per cycle.
#[test]
fn input_bandwidth_invariant() {
    let cfg = ChipConfig::yodann();
    for (k, n_in, n_out) in [(3usize, 8usize, 64usize), (7, 32, 32), (5, 16, 48)] {
        let wl = workload(k, n_in, n_out, 24, 16);
        let run = run_layer(&wl, &cfg, ExecOptions::default());
        let s = &run.stats;
        assert!(
            s.input_words <= s.cycles.total(),
            "k={k}: {} words in {} cycles",
            s.input_words,
            s.cycles.total()
        );
    }
}
