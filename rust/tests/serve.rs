//! The power-aware serving daemon's behavioral contract (ISSUE 8):
//! same seed → bit-identical corner trace and output digest; a power
//! budget is held in steady state by construction; a latency SLO ramps
//! the corner up under a burst and back down when the queue clears; a
//! rising fault rate overrides everything and raises the voltage; and
//! backpressure sheds low-priority traffic first with typed refusals.

use std::sync::Arc;

use yodann::api::{SessionBuilder, Yodann, YodannError};
use yodann::coordinator::SessionLayerSpec;
use yodann::fault::FaultPlan;
use yodann::serve::{
    admit, run, FrameRequest, Governor, GovernorConfig, GovernorMode, Priority, Scenario,
    ServeConfig, ServeReport,
};
use yodann::testkit::Gen;
use yodann::workload::{random_image, BinaryKernels, ScaleBias};

fn chain_specs(seed: u64) -> Vec<SessionLayerSpec> {
    let mut g = Gen::new(seed);
    vec![
        SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 4, 2, 3)),
            scale_bias: Arc::new(ScaleBias::identity(4)),
            relu: false,
            maxpool2: false,
        },
        SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 2, 4, 3)),
            scale_bias: Arc::new(ScaleBias::identity(2)),
            relu: false,
            maxpool2: false,
        },
    ]
}

fn session(plan: FaultPlan, depth: usize) -> Yodann {
    SessionBuilder::new()
        .layers(chain_specs(31))
        .workers(2)
        .max_in_flight(depth)
        .fault_plan(plan)
        .build()
        .unwrap()
}

fn serve_with(plan: FaultPlan, cfg: &ServeConfig) -> ServeReport {
    let mut s = session(plan, 8);
    let mut make = |seed: u64| {
        let mut g = Gen::new(seed);
        random_image(&mut g, 2, 8, 8, 0.05)
    };
    run(&mut s, None, cfg, &mut make, &mut |_| {}).unwrap()
}

#[test]
fn the_corner_trace_and_output_digest_are_seed_stable() {
    for (scenario, mode) in [
        (Scenario::Burst, GovernorMode::PowerBudget { watts: 1e-3 }),
        (Scenario::Sustained, GovernorMode::LatencySlo { seconds: 5e-6 }),
    ] {
        let mut cfg = ServeConfig::new(scenario, mode);
        cfg.total_frames = 32;
        cfg.tick_s = 2e-6;
        let a = serve_with(FaultPlan::disabled(), &cfg);
        let b = serve_with(FaultPlan::disabled(), &cfg);
        // Bit-stable end to end: every trace row, every counter, the
        // digest of every served frame's pixels.
        assert_eq!(a, b, "{scenario:?} serve run must be reproducible");
        assert!(a.frames_served > 0);
    }
}

#[test]
fn a_power_budget_is_held_through_steady_state() {
    let mut cfg =
        ServeConfig::new(Scenario::Sustained, GovernorMode::PowerBudget { watts: 1e-3 });
    cfg.total_frames = 48;
    cfg.tick_s = 2e-6;
    let r = serve_with(FaultPlan::disabled(), &cfg);
    assert!(!r.budget_violated, "steady-state power must stay within the budget");
    for row in r.trace.iter().skip(cfg.warmup_ticks) {
        assert!(
            row.power_w <= row.budget_w + 1e-12,
            "tick {} ran {} W against budget {} W",
            row.tick,
            row.power_w,
            row.budget_w
        );
    }
    assert!(r.mean_power_w > 0.0 && r.mean_power_w <= 1e-3);
    // Nothing offered goes missing: served + shed accounts for all.
    assert_eq!(r.frames_served + r.shed_low + r.shed_high, 48);
}

#[test]
fn an_slo_burst_ramps_the_corner_up_and_back_down() {
    // Calibrate the SLO from the session's own cost model so the test
    // tracks the simulator: one probe frame gives ops/frame, the
    // governor gives the aggregate peak rate at the 0.6 V rail.
    let probe_ops = {
        let mut s = session(FaultPlan::disabled(), 8);
        let mut g = Gen::new(5);
        let ticket = s.submit(random_image(&mut g, 2, 8, 8, 0.05)).unwrap();
        ticket.wait().unwrap().telemetry.ops
    };
    let theta_rail = {
        let s = session(FaultPlan::disabled(), 8);
        let gov = Governor::new(
            &s,
            GovernorMode::LatencySlo { seconds: 1.0 },
            GovernorConfig::default(),
        )
        .unwrap();
        gov.theta(0.6)
    };
    // One frame drains in slo/3 at the rail; a 9-frame burst tick needs
    // 3*slo — over the SLO, so the governor must ramp up, then earn its
    // way back down once the burst clears.
    let slo = 3.0 * probe_ops as f64 / theta_rail;
    let mut cfg =
        ServeConfig::new(Scenario::Burst, GovernorMode::LatencySlo { seconds: slo });
    cfg.total_frames = 48;
    cfg.tick_s = slo / 2.0;
    let r = serve_with(FaultPlan::disabled(), &cfg);
    assert!(
        r.trace.iter().any(|t| t.drain_s > slo),
        "a burst tick must exceed the SLO at the starting corner"
    );
    assert!(r.deadline_misses > 0, "the pre-ramp burst frames must miss the SLO");
    assert!(r.max_v > 0.6 + 1e-9, "the governor must raise the corner under the burst");
    assert!(
        r.final_v < r.max_v,
        "the governor must descend once the queue clears (final {} V, peak {} V)",
        r.final_v,
        r.max_v
    );
}

#[test]
fn fault_pressure_overrides_the_budget_and_raises_the_voltage() {
    // A static bit-error rate high enough that most frames are refused
    // even after the guard-banded retry: the measured fault rate must
    // drive the corner *up* even though the power budget is nowhere
    // near binding and the load never backs up (the tick dwarfs every
    // drain, so no other rule can ask for a higher corner).
    let plan = FaultPlan::seeded(11).ber(5e-4).weights(false);
    let mut cfg = ServeConfig::new(Scenario::Burst, GovernorMode::PowerBudget { watts: 1.0 });
    cfg.total_frames = 48;
    cfg.tick_s = 1e-3; // backlog never grows: only faults can move the corner up
    let r = serve_with(plan, &cfg);
    assert!(r.faults_detected > 0, "the armed plan must refuse some frames");
    assert!(
        r.max_v > 0.7,
        "fault pressure must step the corner up from the 0.6 V rail (peak {} V)",
        r.max_v
    );
    assert!(r.frames_served > 0, "the session must keep serving between faults");
}

#[test]
fn backpressure_sheds_low_priority_first_with_typed_refusals() {
    let mut s = session(FaultPlan::disabled(), 2);
    let offered = vec![
        FrameRequest { priority: Priority::Low, seed: 1 },
        FrameRequest { priority: Priority::High, seed: 2 },
        FrameRequest { priority: Priority::Low, seed: 3 },
        FrameRequest { priority: Priority::High, seed: 4 },
    ];
    let mut make = |seed: u64| {
        let mut g = Gen::new(seed);
        random_image(&mut g, 2, 8, 8, 0.05)
    };
    let (admitted, refused) = admit(&mut s, offered, &mut make);
    assert_eq!(admitted.len(), 2);
    assert!(admitted.iter().all(|a| a.priority == Priority::High));
    assert_eq!(refused.len(), 2);
    for r in &refused {
        assert_eq!(r.priority, Priority::Low);
        assert!(
            matches!(r.error, YodannError::Backpressure { limit: 2, .. }),
            "refusals must be typed backpressure, got {:?}",
            r.error
        );
    }
    for a in admitted {
        a.ticket.wait().unwrap();
    }
    // Capacity comes back once the admitted frames drain.
    let one = vec![FrameRequest { priority: Priority::Low, seed: 9 }];
    let (adm2, ref2) = admit(&mut s, one, &mut make);
    assert_eq!((adm2.len(), ref2.len()), (1, 0));
}
