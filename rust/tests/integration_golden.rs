//! Integration: the cycle simulator's outputs vs the AOT-compiled
//! JAX/Pallas golden model executed through PJRT (`artifacts/*.hlo.txt`).
//!
//! Requires `make artifacts` (skips with a clear message otherwise —
//! `make test` always builds artifacts first).

use yodann::coordinator::check_block;
use yodann::hw::ChipConfig;
use yodann::runtime::Runtime;
use yodann::testkit::Gen;
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, ScaleBias};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP golden tests: {e}");
            None
        }
    }
}

#[test]
fn golden_matches_simulator_k3_dual_mode() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0xA11CE);
    let image = random_image(&mut g, 32, 16, 16, 0.02);
    let kernels = BinaryKernels::random(&mut g, 64, 32, 3);
    let sb = ScaleBias::random(&mut g, 64);
    let report =
        check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true).unwrap();
    assert!(report.ok(), "{:?}", report.first_mismatch);
    assert_eq!(report.samples, 64 * 16 * 16);
}

#[test]
fn golden_matches_simulator_k7() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0xB0B);
    let image = random_image(&mut g, 32, 12, 12, 0.02);
    let kernels = BinaryKernels::random(&mut g, 32, 32, 7);
    let sb = ScaleBias::random(&mut g, 32);
    let report =
        check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true).unwrap();
    assert!(report.ok(), "{:?}", report.first_mismatch);
}

#[test]
fn golden_matches_simulator_k7_valid_padding() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0xC0FFEE);
    let image = random_image(&mut g, 32, 12, 12, 0.02);
    let kernels = BinaryKernels::random(&mut g, 32, 32, 7);
    let sb = ScaleBias::random(&mut g, 32);
    let report =
        check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, false).unwrap();
    assert!(report.ok(), "{:?}", report.first_mismatch);
    assert_eq!(report.samples, 32 * 6 * 6);
}

#[test]
fn golden_matches_simulator_k5_and_k1() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0xD0D0);
    for (k, h, w) in [(5usize, 12, 12), (1, 16, 16)] {
        let image = random_image(&mut g, 32, h, w, 0.02);
        let kernels = BinaryKernels::random(&mut g, 64, 32, k);
        let sb = ScaleBias::random(&mut g, 64);
        let report =
            check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true).unwrap();
        assert!(report.ok(), "k={k}: {:?}", report.first_mismatch);
    }
}

#[test]
fn golden_matches_in_saturating_regime() {
    // Large-amplitude scene: Q7.9 saturation fires; both sides must
    // saturate in the same channel order.
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0xFEED);
    let image = synthetic_scene(&mut g, 32, 16, 16);
    let kernels = BinaryKernels::random(&mut g, 64, 32, 3);
    let sb = ScaleBias::random(&mut g, 64);
    let report =
        check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true).unwrap();
    assert!(report.ok(), "{:?}", report.first_mismatch);
}

#[test]
fn golden_randomized_sweep() {
    // Many seeds on the k3 artifact: the cheap broad net.
    let Some(mut rt) = runtime_or_skip() else { return };
    for seed in 0..5u64 {
        let mut g = Gen::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
        let image = random_image(&mut g, 32, 16, 16, 0.05);
        let kernels = BinaryKernels::random(&mut g, 64, 32, 3);
        let sb = ScaleBias::random(&mut g, 64);
        let report =
            check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true).unwrap();
        assert!(report.ok(), "seed {seed}: {:?}", report.first_mismatch);
    }
}

#[test]
fn unknown_geometry_is_a_clear_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(1);
    let image = random_image(&mut g, 2, 5, 5, 0.02);
    let kernels = BinaryKernels::random(&mut g, 2, 2, 3);
    let sb = ScaleBias::identity(2);
    let err = check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, true)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no artifact"), "{err}");
}

/// Full-stack multi-layer golden: the `smallnet` artifact (3 conv layers
/// with quantized ReLU + 2×2 max-pool, lowered as ONE fused HLO module)
/// vs the same network built layer-by-layer from coordinator-simulated
/// chip blocks plus host ReLU/pool — every layer's chip output feeds the
/// next, so blocking, scale/bias and the inter-layer quantized plumbing
/// must all agree bit-for-bit with the JAX model.
#[test]
fn golden_smallnet_end_to_end() {
    use yodann::coordinator::{run_layer, ExecOptions, LayerWorkload};
    use yodann::workload::Image;

    let Some(mut rt) = runtime_or_skip() else { return };
    let mut g = Gen::new(0x5A11);
    let mut x = random_image(&mut g, 3, 24, 32, 0.05);

    // Matches python/compile/aot.py::SMALLNET_LAYERS.
    let specs: [(usize, usize, bool, f64); 3] =
        [(7, 16, true, 0.05), (7, 32, true, 0.02), (3, 8, false, 0.05)];
    let mut n_in = 3usize;
    let mut params = Vec::new();
    for &(k, n_out, _pool, alpha) in &specs {
        let kernels = BinaryKernels::random(&mut g, n_out, n_in, k);
        let sb = ScaleBias {
            alpha: vec![yodann::fixedpoint::Q2_9.from_f64(alpha); n_out],
            beta: vec![yodann::fixedpoint::Q2_9.from_f64(0.01); n_out],
        };
        params.push((kernels, sb));
        n_in = n_out;
    }

    // Golden: one fused HLO execution.
    let golden = rt.run_smallnet(&x, &params).unwrap();

    // Simulator: layer-by-layer chip blocks + host ReLU/max-pool.
    let cfg = ChipConfig::yodann();
    for (li, &(k, _n_out, pool, _)) in specs.iter().enumerate() {
        let (kernels, sb) = &params[li];
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: x.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        x = run_layer(&wl, &cfg, ExecOptions::default()).output;
        if li + 1 < specs.len() {
            x.data.iter_mut().for_each(|v| *v = (*v).max(0)); // quantized ReLU
        }
        if pool {
            let mut p = Image::zeros(x.c, x.h / 2, x.w / 2);
            for c in 0..x.c {
                for y in 0..p.h {
                    for xx in 0..p.w {
                        *p.at_mut(c, y, xx) = x
                            .at(c, 2 * y, 2 * xx)
                            .max(x.at(c, 2 * y, 2 * xx + 1))
                            .max(x.at(c, 2 * y + 1, 2 * xx))
                            .max(x.at(c, 2 * y + 1, 2 * xx + 1));
                    }
                }
            }
            x = p;
        }
    }
    assert_eq!((x.c, x.h, x.w), (golden.c, golden.h, golden.w));
    assert_eq!(x, golden, "simulated smallnet != JAX smallnet");
}
