//! Analyzer-vs-runtime agreement: the static passes of
//! `yodann::analysis` must be *sound* with respect to what the engines
//! and the serving session actually do.
//!
//! * Range soundness — for fuzzed single-conv layers, every output
//!   pixel produced by **every** engine lies inside the analyzer's
//!   interval, and an `acc_saturation: false` proof means the
//!   cycle-accurate ChannelSummers never clip.
//! * Liveness — every compiled graph the builder can lower (all
//!   `networks::ACCEPTED` ids plus fuzzer-built DAGs) is
//!   lifetime-clean: no use-after-free, no leak.
//! * Contracts — a geometry the analyzer refutes is a frame the
//!   session refuses; a geometry it proves runs end-to-end, inside the
//!   analyzer's output bounds.

use std::sync::Arc;

use yodann::analysis::{analyze_graph, AnalysisOptions, Interval, Pass, Severity};
use yodann::api::SessionBuilder;
use yodann::coordinator::{run_layer_engine, ExecOptions, LayerWorkload, SessionLayerSpec};
use yodann::engine::EngineKind;
use yodann::fixedpoint::Q2_9;
use yodann::hw::ChipConfig;
use yodann::model::graph::{NetworkBuilder, Weights};
use yodann::model::networks;
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, BinaryKernels, Image, ScaleBias};

/// The exact sample interval `random_image` draws from at `amplitude`.
fn image_interval(amplitude: f64) -> Interval {
    let hi = ((Q2_9.max_raw() as f64) * amplitude) as i64;
    Interval::new((-hi).min(-1), hi.max(1))
}

#[test]
fn range_analysis_is_sound_for_every_engine() {
    let cfg = ChipConfig::yodann();
    property("range-soundness-vs-engines", 0x9a11, 24, |g| {
        let k = [1usize, 2, 3, 5, 7][g.range_i64(0, 4) as usize];
        let zero_pad = g.bool();
        let n_in = g.range_i64(1, 4) as usize;
        let n_out = g.range_i64(1, 4) as usize;
        let h = k + g.range_i64(0, 5) as usize;
        let w = k + g.range_i64(0, 5) as usize;
        let amp = [0.02, 0.3, 1.0][g.range_i64(0, 2) as usize];

        let kernels = BinaryKernels::random(g, n_out, n_in, k);
        let sb = ScaleBias::random(g, n_out);

        let mut b = NetworkBuilder::new("range-sound", n_in);
        let x = b.input();
        let c = b.conv(
            "c0",
            x,
            zero_pad,
            Weights::new(Arc::new(kernels.clone()), Arc::new(sb.clone())),
        );
        let graph = b.build(c).compile().expect("single conv compiles");

        let opts = AnalysisOptions { input: image_interval(amp), shape: Some((h, w)) };
        let report = analyze_graph(&graph, &cfg, None, &opts);
        assert!(
            !report
                .findings
                .iter()
                .any(|f| f.pass == Pass::Liveness || f.pass == Pass::Contracts),
            "single conv must be lifetime/geometry clean: {:?}",
            report.findings
        );
        let range = report.ranges.last().expect("conv range computed");

        let wl = LayerWorkload {
            k,
            zero_pad,
            input: random_image(g, n_in, h, w, amp),
            kernels,
            scale_bias: sb,
        };
        // Multi-bit kinds only: the range pass models the Q2.9 datapath,
        // and the binary-activation engines deliberately replace every
        // activation with a full-scale ±1.0 sign — their accumulators
        // are not bounded by the analyzed input interval.
        for kind in EngineKind::MULTI_BIT {
            let run = run_layer_engine(&wl, &cfg, ExecOptions { workers: 2 }, kind);
            for &v in &run.output.data {
                assert!(
                    range.out.contains(v),
                    "{kind:?} produced {v} outside the analyzed interval {} \
                     (k={k}, pad={zero_pad}, {n_in}->{n_out} ch, {h}x{w}, amp={amp})",
                    range.out
                );
            }
            if !range.acc_saturation {
                assert_eq!(
                    run.stats.summer_saturations, 0,
                    "{kind:?} saturated a summer the analyzer proved clean \
                     (k={k}, pad={zero_pad}, {n_in}->{n_out} ch, amp={amp})"
                );
            }
        }
    });
}

#[test]
fn every_accepted_network_analyzes_without_errors() {
    for &id in networks::ACCEPTED {
        let net = networks::network(id).expect("accepted id resolves");
        // The CLI's lowering: chain when the network chains, the graph
        // encoding (AlexNet's kernel split, ResNet shortcuts) otherwise.
        let builder = match SessionLayerSpec::synthetic_network(&net, 42) {
            Ok(specs) => SessionBuilder::new().workers(3).layers(specs),
            Err(_) => {
                let g = networks::graph_network(id, 42)
                    .expect("non-chain networks carry a graph encoding");
                SessionBuilder::new().workers(3).graph(&g)
            }
        };
        let (h, w) = net.img;
        let opts = AnalysisOptions { input: Interval::full_q29(), shape: Some((h, w)) };
        let report = builder.analyze(&opts).expect("accepted networks lower");
        assert!(
            !report.has_errors(),
            "{id}: analyzer found errors: {:?}",
            report
                .findings
                .iter()
                .filter(|f| f.severity == Severity::Error)
                .collect::<Vec<_>>()
        );
        // All four passes actually ran: the default Auto policy lowers
        // to a worker-stripe grid, so shard proofs are included.
        assert!(!report.contracts.skipped, "{id}: contracts must run at a known shape");
        assert!(report.contracts.convs_checked > 0, "{id}: no convs checked");
        assert!(report.contracts.shards_checked > 0, "{id}: Auto policy must prove shards");
        assert!(!report.ranges.is_empty(), "{id}: range pass produced nothing");
        assert!(
            report.liveness.peak_words.is_some(),
            "{id}: peak memory needs the completed shape walk"
        );
    }
}

#[test]
fn fuzzed_dags_are_lifetime_clean() {
    let cfg = ChipConfig::yodann();
    property("dag-liveness", 0xda61, 60, |g| {
        let n_in = 1 + g.range_i64(0, 3) as usize;
        let mut b = NetworkBuilder::new("fuzz-dag", n_in);
        let x = b.input();
        // (node, channels, consumed) — all ops here preserve the map
        // size (zero-padded convs only), so any two nodes can combine.
        let mut nodes = vec![(x, n_in, false)];
        for step in 0..3 + g.range_i64(0, 5) {
            let i = g.range_i64(0, nodes.len() as i64 - 1) as usize;
            let (src, src_ch, _) = nodes[i];
            let node = match g.range_i64(0, 3) {
                0 => {
                    let n_out = 1 + g.range_i64(0, 5) as usize;
                    let k = [1usize, 3, 5][g.range_i64(0, 2) as usize];
                    let w = Weights::seeded(g, n_out, src_ch, k);
                    (b.conv(&format!("c{step}"), src, true, w), n_out)
                }
                1 => (b.relu(src), src_ch),
                2 => {
                    // Residual add needs matching channels; j == i
                    // (doubling) is a legal degenerate case.
                    let j = (0..nodes.len())
                        .filter(|&j| nodes[j].1 == src_ch)
                        .max()
                        .unwrap_or(i);
                    nodes[j].2 = true;
                    (b.add(&format!("a{step}"), &[src, nodes[j].0]), src_ch)
                }
                _ => {
                    let j = g.range_i64(0, nodes.len() as i64 - 1) as usize;
                    nodes[j].2 = true;
                    (b.concat(&format!("k{step}"), &[src, nodes[j].0]), src_ch + nodes[j].1)
                }
            };
            nodes[i].2 = true;
            nodes.push((node.0, node.1, false));
        }
        // Fold every unconsumed node into the output so the graph has
        // no dead branches (the compiler would reject them).
        let leaves: Vec<_> = nodes.iter().filter(|n| !n.2).map(|n| n.0).collect();
        let out = if leaves.len() == 1 { leaves[0] } else { b.concat("out", &leaves) };
        let graph = b.build(out).compile().expect("fuzzed DAG compiles");

        let report = analyze_graph(&graph, &cfg, None, &AnalysisOptions::default());
        let lifetime: Vec<_> =
            report.findings.iter().filter(|f| f.pass == Pass::Liveness).collect();
        assert!(lifetime.is_empty(), "compiled DAG must be lifetime-clean: {lifetime:?}");
        assert!(
            (1..=report.liveness.n_slots).contains(&report.liveness.peak_slots),
            "peak {} out of range for {} slots",
            report.liveness.peak_slots,
            report.liveness.n_slots
        );
    });
}

#[test]
fn contract_errors_agree_with_the_session() {
    let mut g = Gen::new(7);
    let mut b = NetworkBuilder::new("agree", 2);
    let x = b.input();
    let c = b.conv("c0", x, false, Weights::seeded(&mut g, 3, 2, 5));
    let ng = b.build(c);

    // Refuted: a valid-mode k=5 conv has no output rows on a 3-row
    // frame. The analyzer proves it; the session refuses the frame.
    let builder = SessionBuilder::new().workers(1).graph(&ng);
    let opts = AnalysisOptions { input: Interval::full_q29(), shape: Some((3, 16)) };
    let report = builder.analyze(&opts).expect("graph lowers");
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.pass == Pass::Contracts && f.severity == Severity::Error),
        "h < k must be refuted statically: {:?}",
        report.findings
    );
    let mut session = builder.build().expect("build is frame-shape independent");
    assert!(
        session.submit(Image::zeros(2, 3, 16)).is_err(),
        "the session must refuse the frame the analyzer refuted"
    );
    drop(session);

    // Proved: the same net at a workable geometry runs end-to-end, and
    // the frame's outputs respect the analyzer's interval.
    let builder = SessionBuilder::new().workers(1).graph(&ng);
    let opts = AnalysisOptions { input: image_interval(1.0), shape: Some((16, 16)) };
    let report = builder.analyze(&opts).expect("graph lowers");
    assert!(
        !report.findings.iter().any(|f| f.pass == Pass::Contracts),
        "16x16 must prove clean: {:?}",
        report.findings
    );
    let out_range = report.ranges.last().expect("conv range").out;
    let mut session = builder.build().expect("proved geometry builds");
    let results = session
        .run_batch(vec![random_image(&mut g, 2, 16, 16, 1.0)])
        .expect("proved geometry runs");
    for &v in &results[0].output.data {
        assert!(v >= out_range.lo && v <= out_range.hi, "output {v} escapes {out_range}");
    }
}
