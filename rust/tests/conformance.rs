//! The differential conformance harness: every execution path the
//! coordinator can take — engine kind × sharded/unsharded × schedule —
//! must be **bit-identical** on the same workload.
//!
//! The engine matrix keeps growing (cycle-accurate / per-window /
//! raster, now × sharded), and hand-caught geometry bugs like PR 2's
//! `tile_row_skip` clipping show that eyeballing each new path against
//! each old one does not scale. This suite is the regression net:
//!
//! * a seeded fuzzer over ~100 randomized layers — kernel sizes
//!   {1, 2, 3, 5, 7} (2 exercises the asymmetric even-kernel halo),
//!   zero-pad on/off, non-square images, channel counts straddling the
//!   input/output block limits, **thin images with h < k**, thin
//!   vertical tiles, saturating amplitudes — asserting all engine kinds
//!   × sharded/unsharded agree bit-for-bit within each precision family
//!   (the multi-bit Q2.9 kinds among themselves, the binary-activation
//!   XNOR kinds among themselves);
//! * the Table-III networks: every chain network runs through the
//!   serving facade (`yodann::api::Yodann`) under every `ShardPolicy`,
//!   and every network's first conv row (AlexNet's 6×6 split included)
//!   runs sharded-vs-unsharded on every engine kind;
//! * the API-redesign differential: `Yodann::submit`/`wait` vs the
//!   deprecated `NetworkSession::run_batch`, bit-for-bit, over the
//!   engine × policy matrix on two Table-III networks;
//! * the graph-IR differential: residual-add and branch-concat graphs
//!   checked bit-identically against naive host-side compositions of
//!   the same weights, plus AlexNet (§IV-D 11×11 split) and ResNet-18
//!   (shortcut projections) end-to-end — across every engine kind and
//!   shard policy.

use yodann::api::SessionBuilder;
use yodann::coordinator::{
    run_layer_engine, run_layer_sharded, ExecOptions, LayerWorkload, NetworkSession,
    SessionLayerSpec, ShardGrid, ShardPolicy,
};
use yodann::engine::EngineKind;
use yodann::fixedpoint::Q2_9;
use yodann::hw::ChipConfig;
use yodann::model::graph::{NetworkBuilder, NetworkGraph, Weights};
use yodann::model::networks;
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, Image, ScaleBias};

/// Run a batch through the serving facade, returning bare images.
fn facade_batch(
    cfg: ChipConfig,
    kind: EngineKind,
    workers: usize,
    policy: ShardPolicy,
    specs: &[SessionLayerSpec],
    frames: &[Image],
) -> Vec<Image> {
    let mut sess = SessionBuilder::new()
        .chip(cfg)
        .layers(specs.to_vec())
        .engine(kind)
        .workers(workers)
        .shard_policy(policy)
        .max_in_flight(frames.len().max(1))
        .build()
        .expect("conformance specs are valid");
    // Through the non-blocking path on purpose: submit everything, then
    // redeem tickets in order — this is the surface the redesign ships.
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| sess.submit(f.clone()).expect("batch fits the in-flight bound"))
        .collect();
    tickets
        .into_iter()
        .map(|t| t.wait().expect("frame computes").output)
        .collect()
}

#[test]
fn prop_engine_shard_matrix_is_bit_identical() {
    // ~100 randomized layers, every engine kind, each also sharded on a
    // random grid: every path in a precision family must produce the
    // same image.
    property("engine x shard conformance", 0xC04F02, 100, |g| {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * g.range(8, 20); // h_max 8..20: thin tiles for k = 5, 7
        let k = *g.choose(&[1usize, 2, 3, 5, 7]);
        let zero_pad = g.bool();
        // Thin images (h < k) only exist zero-padded; valid mode has no
        // output rows there (enforced by the plan geometry guards).
        let thin = zero_pad && k > 1 && g.range(0, 3) == 0;
        let h = if thin { g.range(1, k - 1) } else { g.range(k.max(2), 18) };
        let w = g.range(k.max(2), 9);
        let n_in = g.range(1, 8); // straddles the 4-channel input block limit
        let n_out = g.range(1, 10); // straddles the 4·streams output block limit
        let amplitude = *g.choose(&[0.02, 0.3, 1.0]); // through Q7.9 saturation
        let wl = LayerWorkload {
            k,
            zero_pad,
            input: random_image(g, n_in, h, w, amplitude),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::random(g, n_out),
        };
        let workers = g.range(1, 4);
        let grid = ShardGrid::new(g.range(1, 4), g.range(1, 3));
        let ctx = format!(
            "k={k} pad={zero_pad} {n_in}->{n_out} {h}x{w} amp={amplitude} \
             workers={workers} grid={grid}"
        );
        // Cross-engine equality holds within each family: the multi-bit
        // kinds compute the chip's Q2.9 function, the binary kinds its
        // sign/XNOR counterpart. Sharded-vs-plain holds for every kind.
        let mut first: [Option<Image>; 2] = [None, None];
        for kind in EngineKind::ALL {
            let plain = run_layer_engine(&wl, &cfg, ExecOptions { workers }, kind).output;
            let sharded =
                run_layer_sharded(&wl, &cfg, ExecOptions { workers }, kind, grid).run.output;
            assert_eq!(plain, sharded, "sharded {} diverges ({ctx})", kind.name());
            match &first[kind.is_binary() as usize] {
                None => first[kind.is_binary() as usize] = Some(plain),
                Some(f) => {
                    assert_eq!(&plain, f, "{} diverges from its family ({ctx})", kind.name())
                }
            }
        }
    });
}

#[test]
fn table_iii_network_sessions_conform_across_policies() {
    // Every Table-III chain network (plus the scene-labeling power
    // workload) through a NetworkSession under every ShardPolicy: all
    // schedules bit-identical, and every functional-family engine
    // (per-window, raster, SIMD, SIMD-forced-scalar) bit-identical to
    // each other on the full chain. The cycle-accurate
    // engine runs each network's first layer only — its full equality
    // with the functional engines is pinned at block granularity by the
    // fuzzer above (and by `engine_equivalence.rs`); a debug-mode cycle
    // simulation of the 512-channel VGG chains would dominate tier-1.
    let cfg = ChipConfig::yodann();
    // Every ShardPolicy variant; the per-shard grid shards both axes
    // (row stripes × output-channel groups), row-bands splits each
    // frame's output rows across the pool.
    let policies = [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::new(2, 2)),
        ShardPolicy::Auto,
        ShardPolicy::RowBands(0),
        ShardPolicy::RowBands(2),
    ];
    let mut nets = networks::all_networks();
    nets.push(networks::scene_labeling());
    let mut chains = 0;
    for net in &nets {
        let mut specs = match SessionLayerSpec::synthetic_network(net, 0xC0F) {
            Ok(s) => s,
            Err(_) => continue, // AlexNet's parallel split rows — no chain
        };
        // Deep chains repeat identical-geometry 512-channel rows; the
        // conformance signal is in the distinct row shapes, so cap the
        // debug-mode cost without losing any (k, channels, pool) shape.
        specs.truncate(9);
        chains += 1;
        let mut g = Gen::new(0xBEEF ^ net.conv_ops());
        let frame = synthetic_scene(&mut g, specs[0].kernels.n_in, 8, 8);
        let mut functional_outs: Vec<(EngineKind, Image)> = Vec::new();
        for kind in EngineKind::ALL {
            let kind_specs = if kind == EngineKind::CycleAccurate {
                specs[..1].to_vec()
            } else {
                specs.clone()
            };
            let mut want: Option<Image> = None;
            for policy in policies {
                let got = facade_batch(
                    cfg,
                    kind,
                    3,
                    policy,
                    &kind_specs,
                    std::slice::from_ref(&frame),
                )
                .pop()
                .unwrap();
                match &want {
                    None => want = Some(got),
                    Some(w) => {
                        assert_eq!(&got, w, "{} on {} under {policy}", net.id, kind.name())
                    }
                }
            }
            if kind != EngineKind::CycleAccurate {
                functional_outs.push((kind, want.unwrap()));
            }
        }
        // Full-chain engine equality is a per-family claim: the XNOR
        // kinds binarize every activation, so they agree with each other
        // but not with the Q2.9 functional family.
        for binary in [false, true] {
            let fam: Vec<_> =
                functional_outs.iter().filter(|(k, _)| k.is_binary() == binary).collect();
            let (ka, oa) = fam[0];
            for (kb, ob) in &fam[1..] {
                assert_eq!(oa, ob, "{} vs {} diverge on {}", ka.name(), kb.name(), net.id);
            }
        }
    }
    assert!(chains >= 5, "only {chains} Table-III chains exercised — matrix too thin");
}

#[test]
fn every_table_iii_first_layer_shards_bit_identically_on_every_engine() {
    // Sharded vs unsharded on each network's first conv row — including
    // AlexNet's 6×6 split row, which no session chain covers — on every
    // engine kind, on the taped-out chip configuration. Output channels
    // are capped so the cycle-accurate legs stay debug-friendly; the
    // row's kernel size and padding are the table's.
    let cfg = ChipConfig::yodann();
    let mut nets = networks::all_networks();
    nets.push(networks::scene_labeling());
    for net in &nets {
        let c = net.conv_layers().next().expect("every Table-III network has conv rows");
        let n_out = c.n_out.min(32);
        let mut g = Gen::new(0xF1857 ^ ((c.k as u64) << 3) ^ net.conv_ops());
        let wl = LayerWorkload {
            k: c.k,
            zero_pad: c.zero_pad,
            input: synthetic_scene(&mut g, c.n_in, 8, 6),
            kernels: BinaryKernels::random(&mut g, n_out, c.n_in, c.k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        };
        for kind in EngineKind::ALL {
            let want = run_layer_engine(&wl, &cfg, ExecOptions { workers: 2 }, kind);
            for grid in [ShardGrid::striped(3), ShardGrid::new(2, 2)] {
                let got = run_layer_sharded(&wl, &cfg, ExecOptions { workers: 3 }, kind, grid);
                assert_eq!(
                    got.run.output,
                    want.output,
                    "{} first layer (k={}) on {} sharded {grid}",
                    net.id,
                    c.k,
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn sharded_executor_agrees_with_sessions_under_per_shard() {
    // Cross-path closure: the standalone sharded layer executor and the
    // session's per-shard schedule implement the same stitch — one
    // single-layer network must come out identical through both.
    use std::sync::Arc;
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0x51A6);
    let kernels = Arc::new(BinaryKernels::random(&mut g, 6, 3, 5));
    let sb = Arc::new(ScaleBias::random(&mut g, 6));
    let frame = synthetic_scene(&mut g, 3, 13, 11);
    let wl = LayerWorkload {
        k: 5,
        zero_pad: true,
        input: frame.clone(),
        kernels: (*kernels).clone(),
        scale_bias: (*sb).clone(),
    };
    let grid = ShardGrid::new(3, 2);
    for kind in EngineKind::ALL {
        let direct =
            run_layer_sharded(&wl, &cfg, ExecOptions { workers: 3 }, kind, grid).run.output;
        let specs = vec![SessionLayerSpec {
            k: 5,
            zero_pad: true,
            kernels: Arc::clone(&kernels),
            scale_bias: Arc::clone(&sb),
            relu: false,
            maxpool2: false,
        }];
        let got = facade_batch(
            cfg,
            kind,
            3,
            ShardPolicy::PerShard(grid),
            &specs,
            std::slice::from_ref(&frame),
        )
        .pop()
        .unwrap();
        assert_eq!(got, direct, "engine {}", kind.name());
    }
}

#[test]
fn prop_row_band_schedule_stitches_bit_identically() {
    use std::sync::Arc;
    // The tentpole's stitching obligation: the within-frame row-band
    // schedule must reproduce the sequential per-frame path exactly on
    // batch = 1 traffic. h_max is shrunk so frames span several
    // vertical tile blocks, and the band counts straddle the block
    // count (fewer bands than blocks, equal, more bands than output
    // rows), across every kernel halo shape — on the raster engine and
    // both SIMD paths, whose k-halo overlap reads are what the stitch
    // has to get right.
    property("row-band stitching", 0x0B0B5, 40, |g| {
        let mut cfg = ChipConfig::tiny(4);
        let k = *g.choose(&[1usize, 2, 3, 5, 7]);
        // h_max stays small (several blocks per frame) but >= k so the
        // plan geometry guard admits every kernel size drawn above.
        let h_max = g.range(k.max(4) + 1, k.max(4) + 5);
        cfg.image_mem_rows = 4 * h_max;
        let zero_pad = g.bool();
        let h = g.range(k.max(2), 3 * h_max + 2); // spans 1..=4 blocks
        let w = g.range(k.max(2), 9);
        let n_in = g.range(1, 6);
        let mid = g.range(1, 8);
        let n_out = g.range(1, 8);
        let k2 = *g.choose(&[1usize, 3]);
        // Two layers so bands stitch through an intermediate map too.
        let specs = vec![
            SessionLayerSpec {
                k,
                zero_pad,
                kernels: Arc::new(BinaryKernels::random(g, mid, n_in, k)),
                scale_bias: Arc::new(ScaleBias::random(g, mid)),
                relu: g.bool(),
                maxpool2: false,
            },
            SessionLayerSpec {
                k: k2,
                zero_pad: true,
                kernels: Arc::new(BinaryKernels::random(g, n_out, mid, k2)),
                scale_bias: Arc::new(ScaleBias::random(g, n_out)),
                relu: false,
                maxpool2: false,
            },
        ];
        let frame = random_image(g, n_in, h, w, 0.3);
        let workers = g.range(1, 4);
        let kinds =
            [EngineKind::Functional, EngineKind::FunctionalSimd, EngineKind::FunctionalSimdScalar];
        for kind in kinds {
            let want = facade_batch(
                cfg,
                kind,
                workers,
                ShardPolicy::PerFrame,
                &specs,
                std::slice::from_ref(&frame),
            )
            .pop()
            .unwrap();
            // `h + 8` is degenerate on purpose: more bands than output
            // rows (and than workers) must clamp, not panic or diverge.
            for bands in [0usize, 1, 3, 8, h + 8] {
                let got = facade_batch(
                    cfg,
                    kind,
                    workers,
                    ShardPolicy::RowBands(bands),
                    &specs,
                    std::slice::from_ref(&frame),
                )
                .pop()
                .unwrap();
                assert_eq!(
                    got,
                    want,
                    "row-bands({bands}) diverges from per-frame: {} k={k}/{k2} \
                     pad={zero_pad} {n_in}->{mid}->{n_out} {h}x{w} h_max={h_max} \
                     workers={workers}",
                    kind.name()
                );
            }
        }
    });
}

// ---------------------------------------------------------------------
// Graph-IR conformance: graphs with residual adds, branch concats and
// the paper's non-chain networks, checked bit-identically against a
// naive host-side composition of the same weights — across every
// engine kind and shard policy.
// ---------------------------------------------------------------------

/// Run one frame through a graph-built serving session.
fn graph_facade_run(
    cfg: ChipConfig,
    kind: EngineKind,
    workers: usize,
    policy: ShardPolicy,
    graph: &NetworkGraph,
    frame: &Image,
) -> Image {
    let mut sess = SessionBuilder::new()
        .chip(cfg)
        .graph(graph)
        .engine(kind)
        .workers(workers)
        .shard_policy(policy)
        .build()
        .expect("conformance graphs compile and build");
    sess.submit(frame.clone()).expect("fits").wait().expect("computes").output
}

/// Naive single-conv reference: the layer executor on the same weights.
fn ref_conv(cfg: &ChipConfig, w: &Weights, zero_pad: bool, input: &Image) -> Image {
    let wl = LayerWorkload {
        k: w.kernels.k,
        zero_pad,
        input: input.clone(),
        kernels: (*w.kernels).clone(),
        scale_bias: (*w.scale_bias).clone(),
    };
    run_layer_engine(&wl, cfg, ExecOptions { workers: 1 }, EngineKind::Functional).output
}

fn ref_relu(mut img: Image) -> Image {
    img.data.iter_mut().for_each(|v| *v = (*v).max(0));
    img
}

fn ref_subsample2(img: &Image) -> Image {
    let mut out = Image::zeros(img.c, img.h.div_ceil(2), img.w.div_ceil(2));
    for c in 0..out.c {
        for y in 0..out.h {
            for x in 0..out.w {
                *out.at_mut(c, y, x) = img.at(c, 2 * y, 2 * x);
            }
        }
    }
    out
}

fn ref_add_sat(a: &Image, b: &Image) -> Image {
    let mut out = a.clone();
    for (o, v) in out.data.iter_mut().zip(b.data.iter()) {
        *o = Q2_9.saturate(*o + *v);
    }
    out
}

fn ref_concat(a: &Image, b: &Image) -> Image {
    assert_eq!((a.h, a.w), (b.h, b.w));
    let mut out = Image::zeros(a.c + b.c, a.h, a.w);
    out.data[..a.data.len()].copy_from_slice(&a.data);
    out.data[a.data.len()..].copy_from_slice(&b.data);
    out
}

const GRAPH_POLICIES: [ShardPolicy; 5] = [
    ShardPolicy::PerFrame,
    ShardPolicy::PerShard(ShardGrid { stripes: 3, out_groups: 1 }),
    ShardPolicy::PerShard(ShardGrid { stripes: 2, out_groups: 2 }),
    ShardPolicy::Auto,
    ShardPolicy::RowBands(2),
];

#[test]
fn residual_add_graph_matches_naive_host_composition() {
    // conv → relu → conv, added to a 1×1 projection of the input, then
    // ReLU — one ResNet basic block with a projection shortcut — vs the
    // same weights composed by hand through the layer executor and
    // host ops.
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0x6AF1);
    let w1 = Weights::seeded(&mut g, 6, 3, 3);
    let w2 = Weights::seeded(&mut g, 6, 6, 3);
    let wp = Weights::seeded(&mut g, 6, 3, 1);
    let mut b = NetworkBuilder::new("res-block", 3);
    let x = b.input();
    let m = b.conv("conv1", x, true, w1.clone());
    let m = b.relu(m);
    let m = b.conv("conv2", m, true, w2.clone());
    let p = b.conv("proj", x, true, wp.clone());
    let s = b.add("add", &[m, p]);
    let out = b.relu(s);
    let graph = b.build(out);

    let frame = synthetic_scene(&mut g, 3, 11, 9);
    let m = ref_relu(ref_conv(&cfg, &w1, true, &frame));
    let m = ref_conv(&cfg, &w2, true, &m);
    let p = ref_conv(&cfg, &wp, true, &frame);
    let want = ref_relu(ref_add_sat(&m, &p));

    for kind in EngineKind::MULTI_BIT {
        for policy in GRAPH_POLICIES {
            let got = graph_facade_run(cfg, kind, 3, policy, &graph, &frame);
            assert_eq!(got, want, "{} under {policy}", kind.name());
        }
    }
    // The binary family computes the BNN version of the block (sign
    // activations at every conv): not the Q2.9 composition above, but
    // the three XNOR engines must agree under every policy.
    assert_xnor_family_agrees(cfg, &graph, &frame);
}

/// All three binary-activation engines produce one bit-identical image
/// on a graph, invariant under every shard policy.
fn assert_xnor_family_agrees(cfg: ChipConfig, graph: &NetworkGraph, frame: &Image) {
    let mut want: Option<Image> = None;
    for kind in EngineKind::XNOR {
        for policy in GRAPH_POLICIES {
            let got = graph_facade_run(cfg, kind, 3, policy, graph, frame);
            match &want {
                None => want = Some(got),
                Some(w) => assert_eq!(&got, w, "{} under {policy}", kind.name()),
            }
        }
    }
}

#[test]
fn branch_concat_graph_matches_naive_host_composition() {
    // Two parallel branches of different kernel size, channel-concated
    // (the AlexNet group-join shape), then subsampled, convolved and
    // pooled — vs the hand composition.
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0xC0CA);
    let wa = Weights::seeded(&mut g, 4, 3, 3);
    let wb = Weights::seeded(&mut g, 5, 3, 5);
    let wc = Weights::seeded(&mut g, 4, 9, 3);
    let mut b = NetworkBuilder::new("branches", 3);
    let x = b.input();
    let ba = b.conv("a", x, true, wa.clone());
    let bb = b.conv("b", x, true, wb.clone());
    let cat = b.concat("cat", &[ba, bb]);
    let sub = b.subsample2(cat);
    let c = b.conv("c", sub, true, wc.clone());
    let pooled = b.maxpool2(c);
    let graph = b.build(pooled);

    let frame = synthetic_scene(&mut g, 3, 12, 10);
    let cat = ref_concat(&ref_conv(&cfg, &wa, true, &frame), &ref_conv(&cfg, &wb, true, &frame));
    let sub = ref_subsample2(&cat);
    let c = ref_conv(&cfg, &wc, true, &sub);
    // 6×5 map pools to 3×2.
    let mut want = Image::zeros(c.c, c.h / 2, c.w / 2);
    for ch in 0..c.c {
        for y in 0..want.h {
            for xx in 0..want.w {
                *want.at_mut(ch, y, xx) = c
                    .at(ch, 2 * y, 2 * xx)
                    .max(c.at(ch, 2 * y, 2 * xx + 1))
                    .max(c.at(ch, 2 * y + 1, 2 * xx))
                    .max(c.at(ch, 2 * y + 1, 2 * xx + 1));
            }
        }
    }

    for kind in EngineKind::MULTI_BIT {
        for policy in GRAPH_POLICIES {
            let got = graph_facade_run(cfg, kind, 3, policy, &graph, &frame);
            assert_eq!(got, want, "{} under {policy}", kind.name());
        }
    }
    assert_xnor_family_agrees(cfg, &graph, &frame);
}

#[test]
fn alexnet_and_resnet18_graphs_run_bit_identically_across_engines_and_policies() {
    // The acceptance obligation: the paper's non-chain networks run
    // end-to-end (no NotASimpleChain), bit-identical across every
    // engine kind and shard policy. Channel widths are divided by 8 so
    // the cycle-accurate legs stay debug-tractable — the topology
    // (AlexNet's 4-way 11×11 split per group, ResNet's projection
    // shortcuts and strides) is the full network's.
    let cfg = ChipConfig::yodann();
    let cases: [(&str, NetworkGraph, (usize, usize)); 2] = [
        ("alexnet", networks::alexnet_graph_scaled(0xA1E, 8), (20, 16)),
        ("resnet18", networks::resnet18_graph_scaled(0x4E5, 8), (16, 12)),
    ];
    for (id, graph, (h, w)) in cases {
        let mut g = Gen::new(0xE2E ^ h as u64);
        let frame = synthetic_scene(&mut g, 3, h, w);
        // Bit-identity is per engine family (Q2.9 vs sign activations).
        let mut want: [Option<Image>; 2] = [None, None];
        for kind in EngineKind::ALL {
            for policy in GRAPH_POLICIES {
                let got = graph_facade_run(cfg, kind, 3, policy, &graph, &frame);
                match &want[kind.is_binary() as usize] {
                    None => want[kind.is_binary() as usize] = Some(got),
                    Some(wnt) => {
                        assert_eq!(&got, wnt, "{id} on {} under {policy}", kind.name())
                    }
                }
            }
        }
    }
}

#[test]
fn full_width_paper_graphs_serve_with_telemetry_intact() {
    // AlexNet and ResNet-18 at full channel width (scaled input),
    // functional engine: the networks the old API rejected with
    // NotASimpleChain now serve frames with per-frame telemetry.
    let cfg = ChipConfig::yodann();
    for (id, graph, (h, w), out_c) in [
        ("alexnet", networks::alexnet_graph(7), (24usize, 20usize), 256usize),
        ("resnet18", networks::resnet18_graph(7), (24, 16), 512),
    ] {
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .graph(&graph)
            .engine(EngineKind::Functional)
            .workers(4)
            .build()
            .unwrap_or_else(|e| panic!("{id} must build: {e}"));
        let mut g = Gen::new(0xAB ^ out_c as u64);
        let frame = synthetic_scene(&mut g, 3, h, w);
        let r = sess.submit(frame).expect("fits").wait().expect("serves");
        assert!(r.telemetry.ops > 0, "{id} must account Eq. 7 ops");
        assert_eq!(r.output.c, out_c, "{id} output channels");
    }
}

#[test]
fn facade_is_bit_identical_to_the_pre_redesign_session() {
    // The redesign's differential obligation: `Yodann::submit`/`wait`
    // must reproduce the deprecated `NetworkSession::run_batch` exactly,
    // for every engine kind × shard policy, on (at least) two Table-III
    // networks. The cycle-accurate legs run the first layer only, like
    // the policy-conformance test above — full-chain engine equality is
    // pinned by the fuzzer at block granularity.
    let cfg = ChipConfig::yodann();
    let policies = [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::striped(3)),
        ShardPolicy::PerShard(ShardGrid::new(2, 2)),
        ShardPolicy::Auto,
        ShardPolicy::RowBands(3),
    ];
    for net in [networks::bc_cifar10(), networks::bc_svhn()] {
        let mut specs =
            SessionLayerSpec::synthetic_network(&net, 0xD1FF).expect("Table-III chain");
        specs.truncate(4);
        let mut g = Gen::new(0xFACADE ^ net.conv_ops());
        let frames: Vec<Image> =
            (0..2).map(|_| synthetic_scene(&mut g, specs[0].kernels.n_in, 8, 8)).collect();
        for kind in EngineKind::ALL {
            let kind_specs = if kind == EngineKind::CycleAccurate {
                specs[..1].to_vec()
            } else {
                specs.clone()
            };
            for policy in policies {
                #[allow(deprecated)] // the differential's whole point
                let legacy = {
                    let mut old =
                        NetworkSession::with_policy(cfg, kind, 3, policy, kind_specs.clone());
                    old.run_batch(frames.clone())
                };
                let new = facade_batch(cfg, kind, 3, policy, &kind_specs, &frames);
                assert_eq!(
                    new,
                    legacy,
                    "facade diverges from NetworkSession: {} {} {policy}",
                    net.id,
                    kind.name()
                );
            }
        }
    }
}
