//! The differential conformance harness: every execution path the
//! coordinator can take — engine kind × sharded/unsharded × schedule —
//! must be **bit-identical** on the same workload.
//!
//! The engine matrix keeps growing (cycle-accurate / per-window /
//! raster, now × sharded), and hand-caught geometry bugs like PR 2's
//! `tile_row_skip` clipping show that eyeballing each new path against
//! each old one does not scale. This suite is the regression net:
//!
//! * a seeded fuzzer over ~100 randomized layers — kernel sizes
//!   {1, 2, 3, 5, 7} (2 exercises the asymmetric even-kernel halo),
//!   zero-pad on/off, non-square images, channel counts straddling the
//!   input/output block limits, **thin images with h < k**, thin
//!   vertical tiles, saturating amplitudes — asserting all engine kinds
//!   × sharded/unsharded agree bit-for-bit;
//! * the Table-III networks: every chain network runs through the
//!   serving facade (`yodann::api::Yodann`) under every `ShardPolicy`,
//!   and every network's first conv row (AlexNet's 6×6 split included)
//!   runs sharded-vs-unsharded on every engine kind;
//! * the API-redesign differential: `Yodann::submit`/`wait` vs the
//!   deprecated `NetworkSession::run_batch`, bit-for-bit, over the
//!   engine × policy matrix on two Table-III networks.

use yodann::api::SessionBuilder;
use yodann::coordinator::{
    run_layer_engine, run_layer_sharded, ExecOptions, LayerWorkload, NetworkSession,
    SessionLayerSpec, ShardGrid, ShardPolicy,
};
use yodann::engine::EngineKind;
use yodann::hw::ChipConfig;
use yodann::model::networks;
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, Image, ScaleBias};

/// Run a batch through the serving facade, returning bare images.
fn facade_batch(
    cfg: ChipConfig,
    kind: EngineKind,
    workers: usize,
    policy: ShardPolicy,
    specs: &[SessionLayerSpec],
    frames: &[Image],
) -> Vec<Image> {
    let mut sess = SessionBuilder::new()
        .chip(cfg)
        .layers(specs.to_vec())
        .engine(kind)
        .workers(workers)
        .shard_policy(policy)
        .max_in_flight(frames.len().max(1))
        .build()
        .expect("conformance specs are valid");
    // Through the non-blocking path on purpose: submit everything, then
    // redeem tickets in order — this is the surface the redesign ships.
    let tickets: Vec<_> = frames
        .iter()
        .map(|f| sess.submit(f.clone()).expect("batch fits the in-flight bound"))
        .collect();
    tickets
        .into_iter()
        .map(|t| t.wait().expect("frame computes").output)
        .collect()
}

#[test]
fn prop_engine_shard_matrix_is_bit_identical() {
    // ~100 randomized layers, every engine kind, each also sharded on a
    // random grid: all six paths must produce the same image.
    property("engine x shard conformance", 0xC04F02, 100, |g| {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * g.range(8, 20); // h_max 8..20: thin tiles for k = 5, 7
        let k = *g.choose(&[1usize, 2, 3, 5, 7]);
        let zero_pad = g.bool();
        // Thin images (h < k) only exist zero-padded; valid mode has no
        // output rows there (enforced by the plan geometry guards).
        let thin = zero_pad && k > 1 && g.range(0, 3) == 0;
        let h = if thin { g.range(1, k - 1) } else { g.range(k.max(2), 18) };
        let w = g.range(k.max(2), 9);
        let n_in = g.range(1, 8); // straddles the 4-channel input block limit
        let n_out = g.range(1, 10); // straddles the 4·streams output block limit
        let amplitude = *g.choose(&[0.02, 0.3, 1.0]); // through Q7.9 saturation
        let wl = LayerWorkload {
            k,
            zero_pad,
            input: random_image(g, n_in, h, w, amplitude),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::random(g, n_out),
        };
        let workers = g.range(1, 4);
        let grid = ShardGrid::new(g.range(1, 4), g.range(1, 3));
        let ctx = format!(
            "k={k} pad={zero_pad} {n_in}->{n_out} {h}x{w} amp={amplitude} \
             workers={workers} grid={grid}"
        );
        let mut first: Option<Image> = None;
        for kind in EngineKind::ALL {
            let plain = run_layer_engine(&wl, &cfg, ExecOptions { workers }, kind).output;
            let sharded =
                run_layer_sharded(&wl, &cfg, ExecOptions { workers }, kind, grid).run.output;
            assert_eq!(plain, sharded, "sharded {} diverges ({ctx})", kind.name());
            match &first {
                None => first = Some(plain),
                Some(f) => {
                    assert_eq!(&plain, f, "{} diverges from cycle-accurate ({ctx})", kind.name())
                }
            }
        }
    });
}

#[test]
fn table_iii_network_sessions_conform_across_policies() {
    // Every Table-III chain network (plus the scene-labeling power
    // workload) through a NetworkSession under every ShardPolicy: all
    // schedules bit-identical, and the two functional engines
    // bit-identical to each other on the full chain. The cycle-accurate
    // engine runs each network's first layer only — its full equality
    // with the functional engines is pinned at block granularity by the
    // fuzzer above (and by `engine_equivalence.rs`); a debug-mode cycle
    // simulation of the 512-channel VGG chains would dominate tier-1.
    let cfg = ChipConfig::yodann();
    // The three ShardPolicy variants; the per-shard grid shards both
    // axes (row stripes × output-channel groups).
    let policies = [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::new(2, 2)),
        ShardPolicy::Auto,
    ];
    let mut nets = networks::all_networks();
    nets.push(networks::scene_labeling());
    let mut chains = 0;
    for net in &nets {
        let mut specs = match SessionLayerSpec::synthetic_network(net, 0xC0F) {
            Ok(s) => s,
            Err(_) => continue, // AlexNet's parallel split rows — no chain
        };
        // Deep chains repeat identical-geometry 512-channel rows; the
        // conformance signal is in the distinct row shapes, so cap the
        // debug-mode cost without losing any (k, channels, pool) shape.
        specs.truncate(9);
        chains += 1;
        let mut g = Gen::new(0xBEEF ^ net.conv_ops());
        let frame = synthetic_scene(&mut g, specs[0].kernels.n_in, 8, 8);
        let mut functional_outs: Vec<(EngineKind, Image)> = Vec::new();
        for kind in EngineKind::ALL {
            let kind_specs = if kind == EngineKind::CycleAccurate {
                specs[..1].to_vec()
            } else {
                specs.clone()
            };
            let mut want: Option<Image> = None;
            for policy in policies {
                let got = facade_batch(
                    cfg,
                    kind,
                    3,
                    policy,
                    &kind_specs,
                    std::slice::from_ref(&frame),
                )
                .pop()
                .unwrap();
                match &want {
                    None => want = Some(got),
                    Some(w) => {
                        assert_eq!(&got, w, "{} on {} under {policy}", net.id, kind.name())
                    }
                }
            }
            if kind != EngineKind::CycleAccurate {
                functional_outs.push((kind, want.unwrap()));
            }
        }
        let (ka, oa) = &functional_outs[0];
        let (kb, ob) = &functional_outs[1];
        assert_eq!(oa, ob, "{} vs {} diverge on {}", ka.name(), kb.name(), net.id);
    }
    assert!(chains >= 5, "only {chains} Table-III chains exercised — matrix too thin");
}

#[test]
fn every_table_iii_first_layer_shards_bit_identically_on_every_engine() {
    // Sharded vs unsharded on each network's first conv row — including
    // AlexNet's 6×6 split row, which no session chain covers — on every
    // engine kind, on the taped-out chip configuration. Output channels
    // are capped so the cycle-accurate legs stay debug-friendly; the
    // row's kernel size and padding are the table's.
    let cfg = ChipConfig::yodann();
    let mut nets = networks::all_networks();
    nets.push(networks::scene_labeling());
    for net in &nets {
        let c = net.conv_layers().next().expect("every Table-III network has conv rows");
        let n_out = c.n_out.min(32);
        let mut g = Gen::new(0xF1857 ^ ((c.k as u64) << 3) ^ net.conv_ops());
        let wl = LayerWorkload {
            k: c.k,
            zero_pad: c.zero_pad,
            input: synthetic_scene(&mut g, c.n_in, 8, 6),
            kernels: BinaryKernels::random(&mut g, n_out, c.n_in, c.k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        };
        for kind in EngineKind::ALL {
            let want = run_layer_engine(&wl, &cfg, ExecOptions { workers: 2 }, kind);
            for grid in [ShardGrid::striped(3), ShardGrid::new(2, 2)] {
                let got = run_layer_sharded(&wl, &cfg, ExecOptions { workers: 3 }, kind, grid);
                assert_eq!(
                    got.run.output,
                    want.output,
                    "{} first layer (k={}) on {} sharded {grid}",
                    net.id,
                    c.k,
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn sharded_executor_agrees_with_sessions_under_per_shard() {
    // Cross-path closure: the standalone sharded layer executor and the
    // session's per-shard schedule implement the same stitch — one
    // single-layer network must come out identical through both.
    use std::sync::Arc;
    let cfg = ChipConfig::tiny(4);
    let mut g = Gen::new(0x51A6);
    let kernels = Arc::new(BinaryKernels::random(&mut g, 6, 3, 5));
    let sb = Arc::new(ScaleBias::random(&mut g, 6));
    let frame = synthetic_scene(&mut g, 3, 13, 11);
    let wl = LayerWorkload {
        k: 5,
        zero_pad: true,
        input: frame.clone(),
        kernels: (*kernels).clone(),
        scale_bias: (*sb).clone(),
    };
    let grid = ShardGrid::new(3, 2);
    for kind in EngineKind::ALL {
        let direct =
            run_layer_sharded(&wl, &cfg, ExecOptions { workers: 3 }, kind, grid).run.output;
        let specs = vec![SessionLayerSpec {
            k: 5,
            zero_pad: true,
            kernels: Arc::clone(&kernels),
            scale_bias: Arc::clone(&sb),
            relu: false,
            maxpool2: false,
        }];
        let got = facade_batch(
            cfg,
            kind,
            3,
            ShardPolicy::PerShard(grid),
            &specs,
            std::slice::from_ref(&frame),
        )
        .pop()
        .unwrap();
        assert_eq!(got, direct, "engine {}", kind.name());
    }
}

#[test]
fn facade_is_bit_identical_to_the_pre_redesign_session() {
    // The redesign's differential obligation: `Yodann::submit`/`wait`
    // must reproduce the deprecated `NetworkSession::run_batch` exactly,
    // for every engine kind × shard policy, on (at least) two Table-III
    // networks. The cycle-accurate legs run the first layer only, like
    // the policy-conformance test above — full-chain engine equality is
    // pinned by the fuzzer at block granularity.
    let cfg = ChipConfig::yodann();
    let policies = [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::striped(3)),
        ShardPolicy::PerShard(ShardGrid::new(2, 2)),
        ShardPolicy::Auto,
    ];
    for net in [networks::bc_cifar10(), networks::bc_svhn()] {
        let mut specs =
            SessionLayerSpec::synthetic_network(&net, 0xD1FF).expect("Table-III chain");
        specs.truncate(4);
        let mut g = Gen::new(0xFACADE ^ net.conv_ops());
        let frames: Vec<Image> =
            (0..2).map(|_| synthetic_scene(&mut g, specs[0].kernels.n_in, 8, 8)).collect();
        for kind in EngineKind::ALL {
            let kind_specs = if kind == EngineKind::CycleAccurate {
                specs[..1].to_vec()
            } else {
                specs.clone()
            };
            for policy in policies {
                #[allow(deprecated)] // the differential's whole point
                let legacy = {
                    let mut old =
                        NetworkSession::with_policy(cfg, kind, 3, policy, kind_specs.clone());
                    old.run_batch(frames.clone())
                };
                let new = facade_batch(cfg, kind, 3, policy, &kind_specs, &frames);
                assert_eq!(
                    new,
                    legacy,
                    "facade diverges from NetworkSession: {} {} {policy}",
                    net.id,
                    kind.name()
                );
            }
        }
    }
}
