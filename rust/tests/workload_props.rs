//! Workload-generator determinism and `Image` accessor edge cases —
//! the properties batched sessions and benchmarks lean on (a frame
//! generator that drifts across calls would silently invalidate every
//! A/B comparison).

use yodann::fixedpoint::Q2_9;
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, Image};

#[test]
fn synthetic_scene_is_deterministic_per_seed() {
    for seed in [0u64, 1, 42, 0xDEAD_BEEF] {
        let a = synthetic_scene(&mut Gen::new(seed), 3, 20, 24);
        let b = synthetic_scene(&mut Gen::new(seed), 3, 20, 24);
        assert_eq!(a, b, "seed {seed}");
    }
}

#[test]
fn synthetic_scene_differs_across_seeds_and_advances_the_generator() {
    let a = synthetic_scene(&mut Gen::new(7), 3, 16, 16);
    let b = synthetic_scene(&mut Gen::new(8), 3, 16, 16);
    assert_ne!(a, b, "distinct seeds must give distinct frames");
    // Consecutive frames from ONE generator differ too (batch generation).
    let mut g = Gen::new(7);
    let f1 = synthetic_scene(&mut g, 3, 16, 16);
    let f2 = synthetic_scene(&mut g, 3, 16, 16);
    assert_ne!(f1, f2, "one generator must not repeat frames");
}

#[test]
fn prop_synthetic_scene_stays_in_q29_for_any_geometry() {
    property("scene in Q2.9", 0x5CE2E, 30, |g| {
        let c = g.range(1, 4);
        let h = g.range(4, 24);
        let w = g.range(4, 24);
        let img = synthetic_scene(g, c, h, w);
        assert_eq!((img.c, img.h, img.w), (c, h, w));
        assert_eq!(img.data.len(), c * h * w);
        for &v in &img.data {
            assert!(Q2_9.contains(v), "{v} outside Q2.9");
        }
    });
}

#[test]
fn random_generators_are_reproducible() {
    let ka = BinaryKernels::random(&mut Gen::new(5), 4, 3, 7);
    let kb = BinaryKernels::random(&mut Gen::new(5), 4, 3, 7);
    assert_eq!(ka.bits, kb.bits);
    let ia = random_image(&mut Gen::new(6), 2, 9, 9, 0.1);
    let ib = random_image(&mut Gen::new(6), 2, 9, 9, 0.1);
    assert_eq!(ia, ib);
}

#[test]
fn at_padded_edges() {
    let mut img = Image::zeros(2, 3, 4);
    for (i, v) in img.data.iter_mut().enumerate() {
        *v = i as i64 + 1;
    }
    // Interior agrees with the checked accessor.
    for c in 0..2 {
        for y in 0..3 {
            for x in 0..4 {
                assert_eq!(img.at_padded(c, y as isize, x as isize), img.at(c, y, x));
            }
        }
    }
    // One past every border reads the zero halo.
    assert_eq!(img.at_padded(0, -1, 0), 0);
    assert_eq!(img.at_padded(0, 0, -1), 0);
    assert_eq!(img.at_padded(0, 3, 0), 0);
    assert_eq!(img.at_padded(0, 0, 4), 0);
    assert_eq!(img.at_padded(1, -1, -1), 0);
    assert_eq!(img.at_padded(1, 3, 4), 0);
    // Far outside too.
    assert_eq!(img.at_padded(1, isize::MIN / 2, isize::MAX / 2), 0);
    // Corners of the valid region are real samples.
    assert_eq!(img.at_padded(0, 0, 0), 1);
    assert_eq!(img.at_padded(1, 2, 3), 24);
}

#[test]
fn at_padded_degenerate_1x1() {
    let mut img = Image::zeros(1, 1, 1);
    *img.at_mut(0, 0, 0) = 99;
    assert_eq!(img.at_padded(0, 0, 0), 99);
    assert_eq!(img.at_padded(0, 1, 0), 0);
    assert_eq!(img.at_padded(0, 0, 1), 0);
    assert_eq!(img.at_padded(0, -1, 0), 0);
}
