//! Property-based tests over the simulator, coordinator and fixed-point
//! substrate (via the in-repo `testkit` harness — the offline registry
//! has no proptest; see Cargo.toml).
//!
//! Each property runs many seeded random cases; failures report the seed
//! and case index for replay.

use yodann::coordinator::{decompose, run_layer, ExecOptions, LayerWorkload};
use yodann::fixedpoint::{self, Q10_18, Q2_9, Q7_9};
use yodann::hw::{BlockJob, Chip, ChipConfig};
use yodann::testkit::{property, Gen};
use yodann::workload::{random_image, reference_conv, BinaryKernels, ScaleBias};

const CASES: usize = 60;

#[test]
fn prop_simulator_matches_reference_conv() {
    // The central functional property: for ANY random geometry the cycle
    // simulator equals the bit-true reference.
    property("sim == reference", 0xEE0, CASES, |g| {
        let k = g.range(1, 7);
        let n_ch = g.range(2, 6);
        let cfg = ChipConfig::tiny(n_ch);
        let n_in = g.range(1, n_ch);
        let n_out = g.range(1, 2 * n_ch);
        let zero_pad = g.bool();
        let h = g.range(k.max(3), 12);
        let w = g.range(k.max(3), 12);
        let image = random_image(g, n_in, h, w, 0.05);
        let kernels = BinaryKernels::random(g, n_out, n_in, k);
        let sb = ScaleBias::random(g, n_out);
        let job = BlockJob {
            k,
            zero_pad,
            image: image.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        if job.validate(&cfg).is_err() {
            return; // geometry outside the chip's envelope — skip
        }
        let res = Chip::new(cfg).run_block(&job);
        let want = reference_conv(&image, &kernels, &sb, zero_pad);
        assert_eq!(res.output, want, "k={k} n_in={n_in} n_out={n_out} pad={zero_pad}");
    });
}

#[test]
fn prop_coordinator_covers_every_output_exactly_once() {
    // Decomposition invariant: each (out-channel, row) pair is produced
    // by exactly one (out-block, tile) and rows_valid partitions the
    // output height.
    property("blocks partition outputs", 0xB10C, CASES, |g| {
        let cfg = ChipConfig::tiny(4);
        let k = *g.choose(&[1usize, 3, 5, 7]);
        let n_in = g.range(1, 12);
        let n_out = g.range(1, 20);
        let h = g.range(k.max(2), 40);
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(g, n_in, h, 6, 0.02),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::identity(n_out),
        };
        let jobs = decompose(&wl, &cfg);
        use std::collections::HashMap;
        let mut cover: HashMap<(usize, usize), usize> = HashMap::new();
        for j in &jobs {
            // Only count one input block per (out, tile) group.
            if j.in_block != 0 {
                continue;
            }
            for o in 0..j.job.kernels.n_out {
                for r in 0..j.rows_valid {
                    *cover.entry((j.out_base + o, j.row_base + r)).or_insert(0) += 1;
                }
            }
        }
        for o in 0..n_out {
            for y in 0..h {
                assert_eq!(cover.get(&(o, y)), Some(&1), "({o},{y}) covered wrong");
            }
        }
    });
}

#[test]
fn prop_blocked_run_equals_reference_small_amplitude() {
    // Routing/batching/state invariant end-to-end: any blocked execution
    // (channel blocks × tiles, any worker count) equals the monolithic
    // reference when amplitudes cannot saturate partials.
    property("blocked == monolithic", 0xC0DE, 25, |g| {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * g.range(8, 16); // small h_max → tiling
        let k = *g.choose(&[1usize, 3, 5]);
        let n_in = g.range(1, 10);
        let n_out = g.range(1, 12);
        let h = g.range(k.max(2), 24);
        let w = g.range(k.max(2), 10);
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(g, n_in, h, w, 0.01),
            kernels: BinaryKernels::random(g, n_out, n_in, k),
            scale_bias: ScaleBias::random(g, n_out),
        };
        let workers = g.range(1, 4);
        let run = run_layer(&wl, &cfg, ExecOptions { workers });
        let want = reference_conv(&wl.input, &wl.kernels, &wl.scale_bias, true);
        assert_eq!(run.output, want);
    });
}

#[test]
fn prop_fixedpoint_resize_bounds() {
    property("resize saturates and floors", 0xF1, 500, |g| {
        let raw = g.range_i64(Q10_18.min_raw(), Q10_18.max_raw());
        let out = fixedpoint::resize(Q10_18, raw, Q2_9);
        assert!(Q2_9.contains(out));
        // Truncation error < 1 LSB and non-positive (floor).
        let exact = raw as f64 / 512.0; // Q10.18 → Q2.9 LSB units
        if Q2_9.contains(exact.floor() as i64) {
            assert_eq!(out, exact.floor() as i64);
        }
    });
}

#[test]
fn prop_scale_bias_monotone_in_acc() {
    // For α ≥ 0 the scale-bias output is monotone non-decreasing in the
    // accumulator — no wrap-around anywhere in the datapath.
    property("scale_bias monotone", 0x5B, 300, |g| {
        let alpha = g.range_i64(0, Q2_9.max_raw());
        let beta = g.range_i64(Q2_9.min_raw(), Q2_9.max_raw());
        let a = g.range_i64(Q7_9.min_raw(), Q7_9.max_raw());
        let b = g.range_i64(a, Q7_9.max_raw());
        let fa = fixedpoint::scale_bias(a, alpha, beta);
        let fb = fixedpoint::scale_bias(b, alpha, beta);
        assert!(fb >= fa, "a={a} b={b} alpha={alpha} beta={beta}: {fa} > {fb}");
    });
}

#[test]
fn prop_summer_saturation_never_wraps() {
    property("summer clamps", 0x5A7, 300, |g| {
        let mut acc = 0i64;
        for _ in 0..g.range(1, 64) {
            let c = g.range_i64(-100_000, 100_000);
            acc = fixedpoint::sat_add(Q7_9, acc, c);
            assert!(Q7_9.contains(acc));
        }
    });
}

#[test]
fn prop_binarization_roundtrip() {
    property("Eq.5 bit mapping", 0xE5, 200, |g| {
        let w = fixedpoint::BinWeight::from_bit(g.bool());
        assert_eq!(fixedpoint::BinWeight::from_bit(w.bit()), w);
        assert_eq!(w.apply(1), w.value());
        let x = g.range_i64(-2048, 2047);
        assert_eq!(w.apply(x), x * w.value());
    });
}

#[test]
fn prop_cycle_count_formula() {
    // Cycles of a zero-padded block follow the closed form:
    //   filter_load + preload + out_w·out_h·max(n_in, ⌈n_out/streams⌉)
    //   + idle-in-compute + flush.
    property("cycle closed form", 0xCC, 30, |g| {
        let n_ch = 4;
        let cfg = ChipConfig::tiny(n_ch);
        let k = *g.choose(&[3usize, 5, 7]);
        let n_in = g.range(1, n_ch);
        let streams = if k == 7 { 1 } else { 2 };
        let n_out = g.range(1, n_ch * streams);
        let h = g.range(k, 10);
        let w = g.range(k, 10);
        let image = random_image(g, n_in, h, w, 0.02);
        let kernels = BinaryKernels::random(g, n_out, n_in, k);
        let job = BlockJob {
            k,
            zero_pad: true,
            image,
            kernels,
            scale_bias: ScaleBias::identity(n_out),
        };
        let res = Chip::new(cfg).run_block(&job);
        let s = &res.stats;
        let m = job.preload_m() as u64;
        let drain = n_out.div_ceil(streams) as u64;
        let per_pixel = (n_in as u64).max(drain);
        let expect = ((n_out * n_in * k * k) as u64).div_ceil(12)  // filter load
            + m * (h as u64) * (n_in as u64) + m * (n_in as u64)   // preload
            + (h * w) as u64 * per_pixel                           // main loop
            + drain; // flush
        assert_eq!(s.cycles.total(), expect, "k={k} n_in={n_in} n_out={n_out} h={h} w={w}");
    });
}
