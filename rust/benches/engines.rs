//! A/B benchmark of the convolution engines: the cycle-accurate chip
//! simulator vs the functional popcount datapath — and, since the raster
//! refactor, the raster-based functional engine vs its PR-1 per-window
//! packing baseline — on the block hot paths that dominate real
//! workloads and on end-to-end batched traffic through the serving
//! facade (`yodann::api::Yodann`, differentially checked against the
//! deprecated `NetworkSession` path). Outputs
//! are asserted bit-identical before any timing, and the results are
//! written to `BENCH_engines.json` (name, ns/iter, frames/s) so the perf
//! trajectory is trackable across PRs (the `speedup/raster-vs-pr1`
//! record is the raster refactor's headline number, and
//! `speedup/simd-vs-raster` the SIMD engine's). The
//! `batch-matrix/<engine>/w<workers>/batch<N>` records sweep batch size
//! × engine × worker count so the latency-vs-throughput crossover of
//! the row-band schedule is pinned in the same file.

use yodann::api::SessionBuilder;
use yodann::bench::{black_box, emit_json_strict, Bencher, JsonRecord};
use yodann::coordinator::{NetworkSession, SessionLayerSpec, ShardGrid, ShardPolicy};
use yodann::engine::{ConvEngine, CycleAccurate, EngineKind, Functional, FunctionalSimd, Xnor, XnorSimd};
use yodann::fault::{FaultPlan, LiveBer};
use yodann::serve::{self, GovernorAction, GovernorMode, Scenario, ServeConfig};
use yodann::hw::{BlockJob, ChipConfig};
use yodann::model::{networks, Precision};
use yodann::power::xnor::{activation_words, ACTIVATION_PLANES_BWN, ACTIVATION_PLANES_XNOR};
use yodann::testkit::Gen;
use yodann::workload::{
    random_image, reference_xnor_conv, synthetic_scene, BinaryKernels, Image, ScaleBias,
};

fn block(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, seed: u64) -> BlockJob {
    let mut g = Gen::new(seed);
    BlockJob {
        k,
        zero_pad: true,
        image: random_image(&mut g, n_in, h, w, 0.02),
        kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
        scale_bias: ScaleBias::random(&mut g, n_out),
    }
}

fn main() {
    let cfg = ChipConfig::yodann();
    let mut b = Bencher::from_env();
    let mut records: Vec<JsonRecord> = Vec::new();

    println!("== block-level A/B: cycle-accurate vs functional ==");
    for (label, job) in [
        // The acceptance hot path: 32x32 channels, native 7x7.
        ("k7_32to32_16x16", block(7, 32, 32, 16, 16, 2)),
        ("k3_32to64_16x16", block(3, 32, 64, 16, 16, 1)),
        ("k5_32to64_12x12", block(5, 32, 64, 12, 12, 3)),
    ] {
        let mut cyc = CycleAccurate::new(cfg);
        let mut fun = Functional::new();
        assert_eq!(
            cyc.run_block(&job).output,
            fun.run_block(&job).output,
            "engines diverge on {label}"
        );
        let sc = b.bench(&format!("cycle/{label}"), || {
            black_box(cyc.run_block(&job));
        });
        let sf = b.bench(&format!("functional/{label}"), || {
            black_box(fun.run_block(&job));
        });
        let speedup = sc.mean.as_secs_f64() / sf.mean.as_secs_f64();
        println!("  -> functional speedup on {label}: {speedup:.1}x (target >= 5x)\n");
        records.push(JsonRecord::from_stats(&sc));
        records.push(JsonRecord::from_stats(&sf));
        records.push(JsonRecord::ratio(&format!("speedup/{label}"), speedup));
    }

    // The raster refactor's A/B: layer-resident bitplane raster vs the
    // PR-1 per-window repacking, same engine arithmetic either side, on
    // the k=3 throughput workload.
    println!("== raster vs PR-1 per-window packing (functional engine, k=3) ==");
    let job = block(3, 32, 64, 16, 16, 1);
    let mut fun = Functional::new();
    let mut pr1 = Functional::per_window();
    assert_eq!(
        fun.run_block(&job).output,
        pr1.run_block(&job).output,
        "raster and per-window functional diverge"
    );
    let sr = b.bench("functional-raster/k3_32to64_16x16", || {
        black_box(fun.run_block(&job));
    });
    let sp = b.bench("functional-pr1/k3_32to64_16x16", || {
        black_box(pr1.run_block(&job));
    });
    let raster_speedup = sp.mean.as_secs_f64() / sr.mean.as_secs_f64();
    println!("  -> raster speedup over PR-1 packing: {raster_speedup:.2}x (target >= 3x)\n");
    records.push(JsonRecord::from_stats(&sr));
    records.push(JsonRecord::from_stats(&sp));
    records.push(JsonRecord::ratio("speedup/raster-vs-pr1", raster_speedup));

    // The SIMD engine's A/B: runtime-dispatched vector window extract +
    // grouped popcount vs the scalar raster engine, same layout either
    // side — the tentpole's headline number. The forced-scalar leg pins
    // the dispatch overhead (it should track `functional-raster` within
    // noise, since the inner loop is byte-for-byte the same).
    let mut simd = FunctionalSimd::new();
    let mut simd_scalar = FunctionalSimd::forced_scalar();
    assert_eq!(
        fun.run_block(&job).output,
        simd.run_block(&job).output,
        "simd and raster functional diverge"
    );
    assert_eq!(
        fun.run_block(&job).output,
        simd_scalar.run_block(&job).output,
        "forced-scalar simd and raster functional diverge"
    );
    println!(
        "== simd ({}) vs scalar raster (functional engine, k=3) ==",
        simd.isa_name()
    );
    let sv = b.bench("functional-simd/k3_32to64_16x16", || {
        black_box(simd.run_block(&job));
    });
    let ss = b.bench("functional-simd-scalar/k3_32to64_16x16", || {
        black_box(simd_scalar.run_block(&job));
    });
    let simd_speedup = sr.mean.as_secs_f64() / sv.mean.as_secs_f64();
    println!("  -> simd ({}) speedup over scalar raster: {simd_speedup:.2}x\n", simd.isa_name());
    records.push(JsonRecord::from_stats(&sv));
    records.push(JsonRecord::from_stats(&ss));
    records.push(JsonRecord::ratio("speedup/simd-vs-raster", simd_speedup));

    // The XNOR family's A/B: binary-activation engines carry one sign
    // plane per (channel, row) instead of 12 bitplanes, so the window
    // gather touches 1/12 the words and the SoP is a single
    // XNOR+popcount. Outputs intentionally differ from the multi-bit
    // family — they are checked against the naive sign reference
    // instead (n_in = 32 = one input block, so the blocked reduction
    // is exact).
    println!("== xnor (binary activations) vs bitplane raster (k=3) ==");
    let mut xnor = Xnor::new();
    let mut xnor_simd = XnorSimd::new();
    let mut xnor_scalar = XnorSimd::forced_scalar();
    let want = reference_xnor_conv(&job.image, &job.kernels, &job.scale_bias, job.zero_pad);
    assert_eq!(xnor.run_block(&job).output, want, "xnor diverges from the sign reference");
    assert_eq!(xnor_simd.run_block(&job).output, want, "xnor-simd diverges");
    assert_eq!(xnor_scalar.run_block(&job).output, want, "xnor-simd-scalar diverges");
    assert_ne!(
        want,
        fun.run_block(&job).output,
        "the precision families must be distinguishable on this workload"
    );
    let sx = b.bench("xnor/k3_32to64_16x16", || {
        black_box(xnor.run_block(&job));
    });
    let sxv = b.bench("xnor-simd/k3_32to64_16x16", || {
        black_box(xnor_simd.run_block(&job));
    });
    let xnor_speedup = sr.mean.as_secs_f64() / sx.mean.as_secs_f64();
    println!("  -> xnor speedup over 12-plane raster: {xnor_speedup:.2}x\n");
    records.push(JsonRecord::from_stats(&sx));
    records.push(JsonRecord::from_stats(&sxv));
    records.push(JsonRecord::ratio("xnor/speedup-vs-raster", xnor_speedup));
    // The structural half of that win, pinned as its own record: the
    // activation words the two modes keep resident for this geometry.
    let words_bwn = activation_words(32, 16, 16, 3, true, ACTIVATION_PLANES_BWN);
    let words_xnor = activation_words(32, 16, 16, 3, true, ACTIVATION_PLANES_XNOR);
    println!(
        "  activation residency 32x16x16 k3: {words_xnor} words (XNOR) vs {words_bwn} (BWN)"
    );
    records.push(JsonRecord::ratio("xnor/activation-words-bwn", words_bwn as f64));
    records.push(JsonRecord::ratio("xnor/activation-words-xnor", words_xnor as f64));
    records.push(JsonRecord::ratio(
        "xnor/activation-words-reduction",
        words_bwn as f64 / words_xnor as f64,
    ));

    // End-to-end batched traffic through the serving facade: the
    // scene-labeling chain (the paper's power-simulation workload) at
    // reduced frame size, one batch per worker-pool fan-out. The
    // functional engines exercise the layer-resident raster path; every
    // engine's facade outputs are first checked bit-for-bit against the
    // deprecated NetworkSession path (the redesign's old-vs-new
    // differential), and the cycle-accurate run lands its per-frame
    // telemetry (cycles, energy) in the emitted records.
    println!("== batched Yodann-facade throughput (scene-labeling chain, 24x32 frames) ==");
    let specs = SessionLayerSpec::synthetic_network(&networks::scene_labeling(), 7)
        .expect("scene-labeling chains");
    let n_frames = 4usize;
    let mut g = Gen::new(99);
    let frames: Vec<Image> =
        (0..n_frames).map(|_| synthetic_scene(&mut g, 3, 24, 32)).collect();
    let mut session_outputs: Vec<Vec<Image>> = Vec::new();
    for kind in [
        EngineKind::CycleAccurate,
        EngineKind::Functional,
        EngineKind::FunctionalPerWindow,
        EngineKind::FunctionalSimd,
        EngineKind::FunctionalSimdScalar,
    ] {
        #[allow(deprecated)] // the old-vs-new differential needs the old path
        let legacy = {
            let mut old = NetworkSession::new(cfg, kind, 4, specs.clone());
            old.run_batch(frames.clone())
        };
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(kind)
            .workers(4)
            .shard_policy(ShardPolicy::PerFrame)
            .max_in_flight(n_frames)
            .build()
            .expect("a valid serving session");
        let results = sess.run_batch(frames.clone()).expect("batch runs");
        if kind == EngineKind::CycleAccurate {
            for r in &results {
                let t = &r.telemetry;
                let base = format!("frame-telemetry/bench/{}/frame{}", t.policy, t.frame_id);
                records.push(JsonRecord::ratio(&format!("{base}/cycles"), t.cycles as f64));
                if let Some(e) = t.energy_j() {
                    records.push(JsonRecord::ratio(&format!("{base}/energy-uj"), e * 1e6));
                }
            }
        }
        let out: Vec<Image> = results.into_iter().map(|r| r.output).collect();
        assert_eq!(
            out,
            legacy,
            "facade diverges from the deprecated session path on {}",
            kind.name()
        );
        session_outputs.push(out);
        let s = b.bench(&format!("session/{}/batch{}", kind.name(), n_frames), || {
            black_box(sess.run_batch(frames.clone()).expect("batch runs"));
        });
        println!("  -> {:.2} frames/s on {}\n", n_frames as f64 / s.mean.as_secs_f64(), kind.name());
        records.push(JsonRecord::with_frames(&s, n_frames as f64));
    }
    for other in &session_outputs[1..] {
        assert_eq!(&session_outputs[0], other, "session engines diverge");
    }
    println!("session outputs bit-identical across engines (and to the deprecated path)");

    // Mixed-precision serving: the same chain with a BWN stem and a
    // binary trunk (layer 1 keeps Q2.9 activations, every later layer
    // runs on the XNOR companion). The record tracks what the
    // precision knob buys end-to-end through the facade against the
    // all-BWN functional run above.
    println!("== mixed-precision serving (BWN stem -> BNN trunk, scene-labeling chain) ==");
    let mut mixed_precision = vec![Precision::Binary; specs.len()];
    mixed_precision[0] = Precision::MultiBit;
    let mut mixed = SessionBuilder::new()
        .chip(cfg)
        .layers(specs.clone())
        .engine(EngineKind::Functional)
        .workers(4)
        .shard_policy(ShardPolicy::PerFrame)
        .max_in_flight(n_frames)
        .precision(mixed_precision)
        .build()
        .expect("a valid mixed-precision session");
    // Differs from the all-BWN stream (the trunk really binarized) but
    // is itself deterministic: two fresh runs must agree bit-for-bit.
    let mixed_out: Vec<Image> = mixed
        .run_batch(frames.clone())
        .expect("mixed batch runs")
        .into_iter()
        .map(|r| r.output)
        .collect();
    assert_ne!(mixed_out, session_outputs[0], "the binary trunk must actually binarize");
    let mixed_again: Vec<Image> = mixed
        .run_batch(frames.clone())
        .expect("mixed batch reruns")
        .into_iter()
        .map(|r| r.output)
        .collect();
    assert_eq!(mixed_out, mixed_again, "mixed-precision serving must be deterministic");
    let sm = b.bench(&format!("session/mixed-precision/batch{n_frames}"), || {
        black_box(mixed.run_batch(frames.clone()).expect("mixed batch runs"));
    });
    println!(
        "  -> {:.2} frames/s with the binary trunk ({} of {} layers XNOR)\n",
        n_frames as f64 / sm.mean.as_secs_f64(),
        specs.len() - 1,
        specs.len()
    );
    records.push(JsonRecord::with_frames(&sm, n_frames as f64));

    // The fault subsystem's off-path contract: a session with an
    // armed-but-disabled FaultPlan must serve bit-identical frames and
    // must not tax the hot path — the checksum seal/verify machinery
    // only engages when a plan actually injects. `fault/disabled-overhead`
    // pins that ratio (~1.0) in the evidence file across PRs.
    println!("== fault-injection off-path overhead (disabled plan, functional engine) ==");
    let mut fault_sessions: Vec<_> = [None, Some(FaultPlan::disabled())]
        .into_iter()
        .map(|plan| {
            let mut builder = SessionBuilder::new()
                .chip(cfg)
                .layers(specs.clone())
                .engine(EngineKind::Functional)
                .workers(4)
                .shard_policy(ShardPolicy::PerFrame)
                .max_in_flight(n_frames);
            if let Some(plan) = plan {
                builder = builder.fault_plan(plan);
            }
            builder.build().expect("a valid serving session")
        })
        .collect();
    let fault_outputs: Vec<Vec<Image>> = fault_sessions
        .iter_mut()
        .map(|sess| {
            sess.run_batch(frames.clone())
                .expect("batch runs")
                .into_iter()
                .map(|r| r.output)
                .collect()
        })
        .collect();
    assert_eq!(
        fault_outputs[0], fault_outputs[1],
        "a disabled fault plan must leave the serving path bit-identical"
    );
    let s_clean = b.bench(&format!("fault/no-plan/batch{n_frames}"), || {
        black_box(fault_sessions[0].run_batch(frames.clone()).expect("batch runs"));
    });
    let s_armed = b.bench(&format!("fault/disabled-plan/batch{n_frames}"), || {
        black_box(fault_sessions[1].run_batch(frames.clone()).expect("batch runs"));
    });
    let fault_overhead = s_armed.mean.as_secs_f64() / s_clean.mean.as_secs_f64();
    println!("  -> disabled-plan overhead: {fault_overhead:.3}x (target ~1.0)\n");
    records.push(JsonRecord::with_frames(&s_clean, n_frames as f64));
    records.push(JsonRecord::with_frames(&s_armed, n_frames as f64));
    records.push(JsonRecord::ratio("fault/disabled-overhead", fault_overhead));

    // Intra-frame shard scaling: the same batch under the per-frame
    // schedule vs per-shard grids of growing stripe count, functional
    // engine, 4 workers. Records land under the `shard-scaling/` schema:
    // `shard-scaling/<policy>/batchN` carries frames/s (and ns/iter),
    // `shard-scaling/speedup-<grid>` carries the ratio over per-frame.
    println!("== intra-frame shard scaling (scene-labeling chain, 2-frame batch) ==");
    let shard_frames: Vec<Image> = frames[..2].to_vec();
    let policies = [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::striped(2)),
        ShardPolicy::PerShard(ShardGrid::striped(4)),
        ShardPolicy::PerShard(ShardGrid::new(2, 2)),
        ShardPolicy::RowBands(2),
        ShardPolicy::RowBands(0),
    ];
    let mut per_frame_s = None;
    let mut shard_outputs: Vec<Vec<Image>> = Vec::new();
    for policy in policies {
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(EngineKind::Functional)
            .workers(4)
            .shard_policy(policy)
            .max_in_flight(shard_frames.len())
            .build()
            .expect("a valid serving session");
        shard_outputs.push(
            sess.run_batch(shard_frames.clone())
                .expect("batch runs")
                .into_iter()
                .map(|r| r.output)
                .collect(),
        );
        let s = b.bench(&format!("shard-scaling/{policy}/batch{}", shard_frames.len()), || {
            black_box(sess.run_batch(shard_frames.clone()).expect("batch runs"));
        });
        println!(
            "  -> {:.2} frames/s under {policy}\n",
            shard_frames.len() as f64 / s.mean.as_secs_f64()
        );
        records.push(JsonRecord::with_frames(&s, shard_frames.len() as f64));
        match policy {
            ShardPolicy::PerFrame => per_frame_s = Some(s.mean.as_secs_f64()),
            ShardPolicy::PerShard(_) | ShardPolicy::RowBands(_) => {
                let ratio = per_frame_s.expect("per-frame measured first") / s.mean.as_secs_f64();
                records.push(JsonRecord::ratio(&format!("shard-scaling/speedup-{policy}"), ratio));
            }
            ShardPolicy::Auto => {}
        }
    }
    for other in &shard_outputs[1..] {
        assert_eq!(&shard_outputs[0], other, "shard policies diverge");
    }
    println!("shard-policy outputs bit-identical across grids");

    // The batch-size × engine × worker-count throughput matrix — a
    // log-log sweep (1, 2, 4, 8 frames × 1, 2, 4 workers) under the
    // Auto schedule, which row-bands the batch=1 column across the pool
    // and stripes larger batches. Records land as
    // `batch-matrix/<engine>/w<workers>/batch<N>` with frames/s, so the
    // latency-vs-throughput crossover (where within-frame banding stops
    // paying and per-frame batching takes over) is trackable across PRs.
    println!("== batch x engine x worker throughput matrix (scene-labeling chain) ==");
    let matrix_pool: Vec<Image> = {
        let mut mg = Gen::new(0xBA7);
        (0..8).map(|_| synthetic_scene(&mut mg, 3, 16, 20)).collect()
    };
    let matrix_kinds =
        [EngineKind::Functional, EngineKind::FunctionalSimd, EngineKind::FunctionalSimdScalar];
    for kind in matrix_kinds {
        for workers in [1usize, 2, 4] {
            let mut sess = SessionBuilder::new()
                .chip(cfg)
                .layers(specs.clone())
                .engine(kind)
                .workers(workers)
                .shard_policy(ShardPolicy::Auto)
                .max_in_flight(matrix_pool.len())
                .build()
                .expect("a valid serving session");
            for batch in [1usize, 2, 4, 8] {
                let batch_frames: Vec<Image> = matrix_pool[..batch].to_vec();
                let s = b.bench(
                    &format!("batch-matrix/{}/w{workers}/batch{batch}", kind.name()),
                    || {
                        black_box(sess.run_batch(batch_frames.clone()).expect("batch runs"));
                    },
                );
                println!(
                    "  {:<24} w{workers} batch{batch}: {:>9.2} frames/s",
                    kind.name(),
                    batch as f64 / s.mean.as_secs_f64()
                );
                records.push(JsonRecord::with_frames(&s, batch as f64));
            }
        }
    }
    println!();

    // Graph-IR serving: ResNet-18's residual topology (width/4, scaled
    // frames) through the facade's graph path — the record that tracks
    // the step-interpreter's overhead across PRs.
    println!("== graph-IR serving (resnet18 graph, width/4, 24x16 frames) ==");
    let graph = networks::resnet18_graph_scaled(11, 4);
    let mut gsess = SessionBuilder::new()
        .chip(cfg)
        .graph(&graph)
        .engine(EngineKind::Functional)
        .workers(4)
        .shard_policy(ShardPolicy::PerFrame)
        .max_in_flight(4)
        .build()
        .expect("the resnet18 graph builds");
    let mut gg = Gen::new(123);
    let gframes: Vec<Image> = (0..4).map(|_| synthetic_scene(&mut gg, 3, 24, 16)).collect();
    let s = b.bench("graph/resnet18-w4/batch4", || {
        black_box(gsess.run_batch(gframes.clone()).expect("graph batch runs"));
    });
    println!(
        "  -> {:.2} frames/s through the residual graph plan\n",
        gframes.len() as f64 / s.mean.as_secs_f64()
    );
    records.push(JsonRecord::with_frames(&s, gframes.len() as f64));

    // The power-aware serving daemon: every governor scenario, run
    // twice on fresh sessions and asserted bit-identical (corner trace,
    // counters, output digest), then recorded as
    // `serve/<scenario>/...` — wall throughput plus the two governor
    // health numbers (steady-state power, final corner). The sustained
    // run must hold its power budget; the thermal run must show the
    // fault-coupled tug-of-war: the throttled budget forces the corner
    // down, the near-threshold bit-error rate bites, and the measured
    // fault rate pulls the corner back up.
    println!("== power-aware serving: DVFS governor scenarios (serve::run) ==");
    let serve_once = |scenario: Scenario, mode: GovernorMode| {
        let (dial, plan) = if scenario.couples_faults() {
            let d = LiveBer::new(0.0);
            let p = FaultPlan::seeded(0xD1A1).live_ber(&d);
            (Some(d), p)
        } else {
            (None, FaultPlan::disabled())
        };
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(EngineKind::Functional)
            .workers(2)
            .shard_policy(ShardPolicy::PerFrame)
            .max_in_flight(8)
            .fault_plan(plan)
            .build()
            .expect("a valid serving session");
        // 60 frames: the thermal scenario's 3-per-tick schedule then
        // spans ticks 0..20, well past the throttle tick, so the
        // fault-coupled phase happens while real frames still flow.
        let mut scfg = ServeConfig::new(scenario, mode);
        scfg.total_frames = 60;
        scfg.tick_s = 1e-4;
        let mut make = |seed: u64| {
            let mut g = Gen::new(seed);
            synthetic_scene(&mut g, 3, 16, 20)
        };
        serve::run(&mut sess, dial.as_ref(), &scfg, &mut make, &mut |_| {})
            .expect("the serve loop runs to completion")
    };
    for scenario in Scenario::ALL {
        let mode = match scenario {
            Scenario::Burst => GovernorMode::LatencySlo { seconds: 5e-5 },
            Scenario::Sustained | Scenario::ThermalThrottle => {
                GovernorMode::PowerBudget { watts: 2e-3 }
            }
        };
        let t0 = std::time::Instant::now();
        let r = serve_once(scenario, mode);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            r,
            serve_once(scenario, mode),
            "{scenario:?} serve run must be bit-stable across fresh sessions"
        );
        match scenario {
            Scenario::Burst => {
                assert!(r.max_v > 0.6 + 1e-9, "the burst must ramp the corner off the rail");
            }
            Scenario::Sustained => {
                assert!(!r.budget_violated, "sustained serving must hold its power budget");
            }
            Scenario::ThermalThrottle => {
                assert!(r.min_v < 0.9 - 1e-9, "the throttle must force the corner down");
                assert!(r.faults_detected > 0, "the near-threshold corners must fault");
                // The acceptance demo: post-throttle, the measured
                // fault rate breaches the backoff threshold and the
                // governor's reliability override steps the supply up
                // against the collapsed budget.
                assert!(
                    r.trace.iter().any(|t| t.tick > Scenario::THROTTLE_AFTER_TICKS
                        && t.fault_rate > 0.05
                        && t.action == GovernorAction::StepUp),
                    "fault pressure must pull the corner back up post-throttle"
                );
            }
        }
        println!(
            "  {:<10} {:>3} ticks, {:>2}/60 served, corner {:.3} -> {:.3} V \
             (visited [{:.3}, {:.3}]), mean {:.3} mW, {} faults, {} misses",
            scenario.name(),
            r.trace.len(),
            r.frames_served,
            r.trace.first().map_or(0.0, |t| t.v),
            r.final_v,
            r.min_v,
            r.max_v,
            r.mean_power_w * 1e3,
            r.faults_detected,
            r.deadline_misses,
        );
        let served = r.frames_served.max(1) as f64;
        records.push(JsonRecord {
            name: format!("serve/{}/run", scenario.name()),
            ns_per_iter: wall * 1e9 / served,
            frames_per_s: Some(served / wall.max(1e-9)),
        });
        records.push(JsonRecord::ratio(
            &format!("serve/{}/mean-power-mw", scenario.name()),
            r.mean_power_w * 1e3,
        ));
        records
            .push(JsonRecord::ratio(&format!("serve/{}/final-corner-v", scenario.name()), r.final_v));
    }
    println!();

    // Anchor at the workspace root regardless of cargo's bench cwd, so
    // the checked-in evidence file is the one that gets refreshed. The
    // emission is strict: an empty or placeholder record set aborts the
    // bench with a non-zero exit instead of clobbering real numbers.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
    if let Err(e) = emit_json_strict(path, "engines", &records) {
        eprintln!("refusing to write {path}: {e}");
        std::process::exit(1);
    }
    println!("wrote {path} ({} records)", records.len());
}
