//! Bench + regeneration of **Table I** (fixed-point Q2.9 vs binary 8×8):
//! prints the reproduced table with paper deltas and times both the
//! analytic generation and the cycle simulator running the two
//! architectures' functional models on the same workload.

use yodann::bench::{black_box, Bencher};
use yodann::hw::baseline::{q29_conv, Q29Kernels};
use yodann::hw::{BlockJob, Chip, ChipConfig};
use yodann::report::tables;
use yodann::testkit::Gen;
use yodann::workload::{random_image, ScaleBias};

fn main() {
    println!("{}", tables::table1().render());

    let mut b = Bencher::from_env();
    b.bench("table1_generation", || {
        black_box(tables::table1());
    });

    // Functional cost of the two datapaths on identical work: binary
    // complement-mux vs 12×12-bit multiply (the architectural argument).
    let mut g = Gen::new(3);
    let image = random_image(&mut g, 8, 16, 16, 0.02);
    let q29 = Q29Kernels::random(&mut g, 8, 8, 7);
    let bin = q29.signs();
    let sb = ScaleBias::random(&mut g, 8);

    let cfg = ChipConfig::bin8();
    let job = BlockJob {
        k: 7,
        zero_pad: true,
        image: image.clone(),
        kernels: bin,
        scale_bias: sb.clone(),
    };
    let mut chip = Chip::new(cfg);
    let s = b.bench("bin8_block_sim (cycle-accurate)", || {
        black_box(chip.run_block(&job));
    });
    let cycles = chip.run_block(&job).stats.cycles.total();
    println!(
        "  -> simulation speed: {:.2} Mcycles/s",
        s.per_second(cycles as f64) / 1e6
    );

    b.bench("q29_block_functional (12-bit MACs)", || {
        black_box(q29_conv(&image, &q29, &sb, true));
    });
}
