//! Bench + regeneration of **Table II** (device energy efficiency by
//! filter size × architecture at 400 MHz), plus the dual-filter-mode
//! ablation: what the 3×3/5×5 modes buy over zero-padding into 7×7.

use yodann::bench::{black_box, Bencher};
use yodann::power::ArchId;
use yodann::report::tables;

fn main() {
    println!("{}", tables::table2().render());

    // Ablation (DESIGN.md design-choice): dual-filter modes vs zero-pad
    // into the 7×7 slot on the final chip.
    println!("ablation — dual-filter modes vs zero-padding into 7x7 (32x32 chip, GOp/s/W):");
    for k in [3usize, 5] {
        let multi = tables::table2_cell(ArchId::Bin32Multi, k);
        // Fixed-kernel variant zero-pads into 7×7.
        let padded = tables::table2_cell(ArchId::Bin32Fixed, k);
        println!(
            "  {k}x{k}: dual mode {multi:.0} vs zero-padded {padded:.0}  ({:.2}x)",
            multi / padded
        );
    }
    println!();

    let mut b = Bencher::from_env();
    b.bench("table2_generation", || {
        black_box(tables::table2());
    });
    b.bench("table2_single_cell", || {
        black_box(tables::table2_cell(ArchId::Bin32Multi, 3));
    });
}
