//! Bench + regeneration of **Figures 2, 6, 11, 12 and 13** as printed
//! data series.

use yodann::bench::{black_box, Bencher};
use yodann::power::{metric_area_mge, ArchId};
use yodann::report::figures;

fn main() {
    // Fig. 2
    let f2 = figures::fig2();
    println!("Fig. 2 — conv vs other layers (scene-labeling CNN [13]):");
    println!(
        "  conv {:.2} GOp vs other {:.2} MOp per frame (op share {:.4});",
        f2.conv_ops as f64 / 1e9,
        f2.other_ops as f64 / 1e6,
        f2.conv_op_share
    );
    println!(
        "  measured time shares: CPU {:.0}% / GPU {:.0}% conv -> non-conv layers are {:.0}x/{:.0}x less efficient per op\n",
        f2.cpu_conv_time_share * 100.0,
        f2.gpu_conv_time_share * 100.0,
        f2.cpu_other_slowdown,
        f2.gpu_other_slowdown
    );

    // Fig. 6
    println!("Fig. 6 — area breakdown (kGE):");
    for (arch, a) in figures::fig6() {
        println!(
            "  {:<24} mem {:>6.1} | filter {:>6.1} | SoP {:>6.1} | imgbank {:>6.1} | other {:>6.1} | total {:>7.1}",
            arch.name(), a.memory, a.filter_bank, a.sop, a.image_bank,
            a.scale_bias + a.other, a.total_kge()
        );
    }
    println!();

    // Fig. 11
    println!("Fig. 11 — V sweep (baseline vs YodaNN):");
    for arch in [ArchId::Q29Fixed8, ArchId::Bin32Multi] {
        println!("  {}:", arch.name());
        for p in figures::fig11_sweep(arch, 7) {
            println!(
                "    {:.2} V  {:>8.1} MHz  {:>9.1} GOp/s  {:>7.2} TOp/s/W",
                p.v, p.f_mhz, p.theta_gops, p.en_eff_tops_w
            );
        }
    }
    println!();

    // Fig. 12
    println!("Fig. 12 — core power breakdown @400 MHz, 1.2 V (mW):");
    for (arch, b) in figures::fig12_at_400mhz() {
        println!(
            "  {:<24} mem {:>5.1} | SoP {:>5.1} | filter {:>5.1} | sb {:>4.2} | other {:>4.1} | total {:>6.1}",
            arch.name(),
            b.memory * 1e3,
            b.sop * 1e3,
            b.filter_bank * 1e3,
            b.scale_bias * 1e3,
            b.other * 1e3,
            b.total() * 1e3
        );
    }
    println!();

    // Fig. 13
    println!("Fig. 13 — pareto (TOp/s/W, GOp/s/MGE):");
    for p in figures::fig13(7) {
        println!(
            "  {:<18} {:>8.2} {:>10.1}{}",
            p.name,
            p.en_eff,
            p.area_eff,
            if p.ours { "  <- ours" } else { "" }
        );
    }
    let _ = metric_area_mge(ArchId::Bin32Multi);
    println!();

    let mut b = Bencher::from_env();
    b.bench("fig11_sweep_13pts", || {
        black_box(figures::fig11_sweep(ArchId::Bin32Multi, 13));
    });
    b.bench("fig13_pareto", || {
        black_box(figures::fig13(13));
    });
    b.bench("fig2_op_model", || {
        black_box(figures::fig2());
    });
}
