//! Bench + regeneration of **Tables III, IV and V**: the per-layer and
//! per-network evaluations of every CNN in the paper, at both operating
//! corners, with paper deltas.

use yodann::bench::{black_box, Bencher};
use yodann::model::{evaluate_network, networks, Corner};
use yodann::report::tables;

fn main() {
    // Table III for every network at the energy-optimal corner (the
    // paper prints the 0.6 V variant).
    for net in networks::all_networks() {
        println!("{}", tables::table3(net.id, Corner::energy_optimal()).render());
    }
    println!("{}", tables::table45(Corner::energy_optimal()).render());
    println!("{}", tables::table45(Corner::throughput_optimal()).render());

    let mut b = Bencher::from_env();
    b.bench("table3_all_networks", || {
        for net in networks::all_networks() {
            black_box(tables::table3(net.id, Corner::energy_optimal()));
        }
    });
    b.bench("table4_and_5", || {
        black_box(tables::table45(Corner::energy_optimal()));
        black_box(tables::table45(Corner::throughput_optimal()));
    });
    let vgg = networks::vgg19();
    b.bench("evaluate_network(vgg19)", || {
        black_box(evaluate_network(&vgg, Corner::energy_optimal()));
    });
}
