//! Hot-path benchmark of the cycle-accurate simulator itself — the
//! subject of the §Perf optimization pass (EXPERIMENTS.md). Reports
//! simulated Mcycles/s for the configurations that dominate real
//! workloads, plus the end-to-end layer path through the coordinator.

use yodann::bench::{black_box, emit_json, Bencher, JsonRecord};
use yodann::coordinator::{run_layer, ExecOptions, LayerWorkload};
use yodann::hw::{BlockJob, Chip, ChipConfig};
use yodann::testkit::Gen;
use yodann::workload::{random_image, BinaryKernels, ScaleBias};

fn block(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, seed: u64) -> BlockJob {
    let mut g = Gen::new(seed);
    BlockJob {
        k,
        zero_pad: true,
        image: random_image(&mut g, n_in, h, w, 0.02),
        kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
        scale_bias: ScaleBias::random(&mut g, n_out),
    }
}

fn main() {
    let cfg = ChipConfig::yodann();
    let mut b = Bencher::from_env();

    for (label, job) in [
        ("k3_32to64_16x16 (dual mode)", block(3, 32, 64, 16, 16, 1)),
        ("k7_32to32_16x16 (native)", block(7, 32, 32, 16, 16, 2)),
        ("k5_32to64_12x12 (dual mode)", block(5, 32, 64, 12, 12, 3)),
    ] {
        let mut chip = Chip::new(cfg);
        let cycles = chip.run_block(&job).stats.cycles.total();
        let stats = b.bench(label, || {
            black_box(chip.run_block(&job));
        });
        println!(
            "  -> {:.2} Mcycles/s simulated ({} cycles/block), {:.1} Mop/s datapath",
            stats.per_second(cycles as f64) / 1e6,
            cycles,
            stats.per_second(chip.run_block(&job).stats.useful_ops as f64) / 1e6
        );
    }

    // End-to-end layer through the coordinator (block decomposition +
    // worker pool + reduction): a BC-Cifar-10 L2-shaped layer.
    let mut g = Gen::new(9);
    let wl = LayerWorkload {
        k: 3,
        zero_pad: true,
        input: random_image(&mut g, 128, 32, 32, 0.02),
        kernels: BinaryKernels::random(&mut g, 128, 128, 3),
        scale_bias: ScaleBias::random(&mut g, 128),
    };
    let cycles = run_layer(&wl, &cfg, ExecOptions::default()).stats.cycles.total();
    let s = b.bench("layer_bc_cifar10_L2 (128->128, 32x32)", || {
        black_box(run_layer(&wl, &cfg, ExecOptions::default()));
    });
    println!(
        "  -> {:.2} Mcycles/s through coordinator ({} simulated cycles)",
        s.per_second(cycles as f64) / 1e6,
        cycles
    );

    // Machine-readable trajectory record (name, ns/iter, frames/s),
    // anchored at the workspace root regardless of cargo's bench cwd.
    let records: Vec<JsonRecord> = b.results().iter().map(JsonRecord::from_stats).collect();
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_hotpath.json");
    emit_json(path, "sim_hotpath", &records).expect("write BENCH_sim_hotpath.json");
    println!("wrote {path} ({} records)", records.len());
}
